//! Paged KV-cache pool: one shared arena per engine, page tables per
//! session.
//!
//! At production concurrency the capacity ceiling is KV memory, not
//! weights: every live session's contiguous [`KvCache`] grows without
//! bound and holds its high-water allocation until retirement. This
//! module replaces that with a block allocator in the vLLM style:
//!
//! * [`KvPool`] owns the arena — `max_pages` fixed-size pages (or an
//!   unbounded, grow-on-demand arena when `max_pages == 0`), a LIFO free
//!   list, and per-page owner tracking. Admission *reserves* a session's
//!   worst-case page count up front, so a session that was admitted can
//!   never starve mid-decode: pages are drawn from the reservation as
//!   rows are appended and returned to it on `truncate`.
//! * [`PagedKvCache`] is the per-session handle: page tables (one
//!   `Vec<u32>` per layer) instead of buffers. It implements the same
//!   [`KvSeq`] contract as the contiguous cache, and `truncate`, `clear`
//!   and `Drop` return pages to the free list — thousands of sessions
//!   share bounded memory.
//! * [`PageStore`] makes page *storage* pluggable: [`KvStoreKind::F64Dense`]
//!   stores rows as plain f64 (bitwise identical to the contiguous
//!   oracle — pinned by parity tests at several page sizes), and
//!   [`KvStoreKind::Int8Group`] quantizes each cached row with the
//!   crate's uniform min-max machinery (one 8-bit group per row per K
//!   and per V, dequantized on the attention read), cutting page bytes
//!   ~4× under the [`KV_INT8_NLL_REL_TOL`] drift guardrail.
//!
//! Debug poison: freed pages are filled with NaN (f64) / NaN-scale
//! `0xFF` codes (int8) by default, so a stale page table that survives
//! release surfaces immediately as NaN logits instead of silently
//! reading another session's rows.

use std::cell::RefCell;
use std::rc::Rc;

use crate::model::kv::{KvCache, KvSeq};
use crate::model::ModelConfig;
use crate::quant::uniform::{fit_minmax, quantize_value, UniformGroup};

/// Relative mean-NLL drift allowed for the int8-grouped page store
/// against the f64 oracle (the perplexity-proxy guardrail, same style
/// as the f32 path's `F32_LOSS_REL_TOL`).
pub const KV_INT8_NLL_REL_TOL: f64 = 0.05;

/// Owner value of an unallocated page.
const FREE: u64 = u64::MAX;

/// Unbounded pools grow the arena in chunks of this many pages.
const GROW_CHUNK: usize = 8;

/// Which [`PageStore`] backs the pool's pages.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvStoreKind {
    /// Plain f64 rows — bitwise identical to the contiguous oracle.
    F64Dense,
    /// Per-row 8-bit min-max groups (scale+zero per row per K and V),
    /// dequantized on the attention read. ~4× denser than f64.
    Int8Group,
}

impl KvStoreKind {
    /// CLI name (`--kv-store`).
    pub fn name(self) -> &'static str {
        match self {
            KvStoreKind::F64Dense => "f64",
            KvStoreKind::Int8Group => "int8",
        }
    }

    /// Parse the CLI name; `None` on anything but `f64` / `int8`.
    pub fn parse(s: &str) -> Option<KvStoreKind> {
        match s {
            "f64" => Some(KvStoreKind::F64Dense),
            "int8" => Some(KvStoreKind::Int8Group),
            _ => None,
        }
    }
}

/// Pluggable page storage: the pool addresses pages by index, the store
/// decides how a row is represented. `write_row`/`read_*_row` move one
/// `[d_model]` row at a time — the granularity at which the generic
/// attention loop reads the cache.
pub trait PageStore {
    /// Which store this is (for stats and the CLI).
    fn kind(&self) -> KvStoreKind;
    /// Resident bytes of one page (rows + any per-row metadata).
    fn page_bytes(&self) -> usize;
    /// Pages currently backed by storage.
    fn n_pages(&self) -> usize;
    /// Grow storage to at least `n` pages (zero-initialized).
    fn grow_to(&mut self, n: usize);
    /// Store one K row and one V row (`[d_model]` each) at `slot` of `page`.
    fn write_row(&mut self, page: u32, slot: usize, k: &[f64], v: &[f64]);
    /// Read the K row at `slot` of `page` into `out` (`[d_model]`).
    fn read_k_row(&self, page: u32, slot: usize, out: &mut [f64]);
    /// Read the V row at `slot` of `page` into `out` (`[d_model]`).
    fn read_v_row(&self, page: u32, slot: usize, out: &mut [f64]);
    /// Debug-poison a freed page so stale reads surface as NaN.
    fn poison(&mut self, page: u32);
}

// ---------------------------------------------------------------------------
// f64 dense pages — the bitwise-identical store

/// Dense f64 page storage: rows are stored exactly as appended, so the
/// paged path reproduces the contiguous oracle bit for bit.
struct F64Dense {
    page_rows: usize,
    d: usize,
    k: Vec<f64>,
    v: Vec<f64>,
}

impl F64Dense {
    fn new(page_rows: usize, d: usize) -> F64Dense {
        F64Dense { page_rows, d, k: Vec::new(), v: Vec::new() }
    }

    #[inline]
    fn off(&self, page: u32, slot: usize) -> usize {
        (page as usize * self.page_rows + slot) * self.d
    }
}

impl PageStore for F64Dense {
    fn kind(&self) -> KvStoreKind {
        KvStoreKind::F64Dense
    }
    fn page_bytes(&self) -> usize {
        self.page_rows * self.d * 2 * std::mem::size_of::<f64>()
    }
    fn n_pages(&self) -> usize {
        self.k.len() / (self.page_rows * self.d)
    }
    fn grow_to(&mut self, n: usize) {
        let want = n * self.page_rows * self.d;
        if want > self.k.len() {
            self.k.resize(want, 0.0);
            self.v.resize(want, 0.0);
        }
    }
    fn write_row(&mut self, page: u32, slot: usize, k: &[f64], v: &[f64]) {
        let off = self.off(page, slot);
        self.k[off..off + self.d].copy_from_slice(k);
        self.v[off..off + self.d].copy_from_slice(v);
    }
    fn read_k_row(&self, page: u32, slot: usize, out: &mut [f64]) {
        let off = self.off(page, slot);
        out.copy_from_slice(&self.k[off..off + self.d]);
    }
    fn read_v_row(&self, page: u32, slot: usize, out: &mut [f64]) {
        let off = self.off(page, slot);
        out.copy_from_slice(&self.v[off..off + self.d]);
    }
    fn poison(&mut self, page: u32) {
        let off = self.off(page, 0);
        let n = self.page_rows * self.d;
        for x in &mut self.k[off..off + n] {
            *x = f64::NAN;
        }
        for x in &mut self.v[off..off + n] {
            *x = f64::NAN;
        }
    }
}

// ---------------------------------------------------------------------------
// int8 grouped pages — quantized storage, dequant on read

/// Int8 page storage: each cached row is one asymmetric min-max group
/// (8-bit codes + a 16-byte scale/zero pair), fitted at append time with
/// the crate's uniform machinery and dequantized on the attention read.
/// Deterministic: the codes are a pure function of the appended row.
struct Int8Group {
    page_rows: usize,
    d: usize,
    k_codes: Vec<u8>,
    v_codes: Vec<u8>,
    k_groups: Vec<UniformGroup>,
    v_groups: Vec<UniformGroup>,
}

impl Int8Group {
    fn new(page_rows: usize, d: usize) -> Int8Group {
        Int8Group {
            page_rows,
            d,
            k_codes: Vec::new(),
            v_codes: Vec::new(),
            k_groups: Vec::new(),
            v_groups: Vec::new(),
        }
    }

    #[inline]
    fn row_index(&self, page: u32, slot: usize) -> usize {
        page as usize * self.page_rows + slot
    }

    fn quantize_into(codes: &mut [u8], group: &mut UniformGroup, row: &[f64]) {
        let g = fit_minmax(row, 8);
        *group = g;
        for (c, &x) in codes.iter_mut().zip(row) {
            // 8-bit codes: quantize_value clamps to 0..=255, fits u8
            let (code, _) = quantize_value(x, &g, 8);
            *c = code as u8;
        }
    }

    fn dequant_row(codes: &[u8], g: &UniformGroup, out: &mut [f64]) {
        // detlint: hot(kv-dequant-read) — the fused dequant on the
        // attention read path runs once per cached-row access per step;
        // it must stay allocation-free (callers lend the cache's
        // preallocated scratch row).
        for (o, &c) in out.iter_mut().zip(codes) {
            *o = g.zero + c as f64 * g.scale;
        }
        // detlint: endhot
    }
}

impl PageStore for Int8Group {
    fn kind(&self) -> KvStoreKind {
        KvStoreKind::Int8Group
    }
    fn page_bytes(&self) -> usize {
        // codes for K and V + one (scale, zero) pair per row for each
        self.page_rows * self.d * 2 + self.page_rows * 2 * std::mem::size_of::<UniformGroup>()
    }
    fn n_pages(&self) -> usize {
        self.k_codes.len() / (self.page_rows * self.d)
    }
    fn grow_to(&mut self, n: usize) {
        let want = n * self.page_rows * self.d;
        if want > self.k_codes.len() {
            self.k_codes.resize(want, 0);
            self.v_codes.resize(want, 0);
            let groups = n * self.page_rows;
            let zero = UniformGroup { scale: 1.0, zero: 0.0 };
            self.k_groups.resize(groups, zero);
            self.v_groups.resize(groups, zero);
        }
    }
    fn write_row(&mut self, page: u32, slot: usize, k: &[f64], v: &[f64]) {
        let ri = self.row_index(page, slot);
        let base = ri * self.d;
        Int8Group::quantize_into(&mut self.k_codes[base..base + self.d], &mut self.k_groups[ri], k);
        Int8Group::quantize_into(&mut self.v_codes[base..base + self.d], &mut self.v_groups[ri], v);
    }
    fn read_k_row(&self, page: u32, slot: usize, out: &mut [f64]) {
        let ri = self.row_index(page, slot);
        let base = ri * self.d;
        Int8Group::dequant_row(&self.k_codes[base..base + self.d], &self.k_groups[ri], out);
    }
    fn read_v_row(&self, page: u32, slot: usize, out: &mut [f64]) {
        let ri = self.row_index(page, slot);
        let base = ri * self.d;
        Int8Group::dequant_row(&self.v_codes[base..base + self.d], &self.v_groups[ri], out);
    }
    fn poison(&mut self, page: u32) {
        let ri0 = self.row_index(page, 0);
        let base = ri0 * self.d;
        let n = self.page_rows * self.d;
        for c in &mut self.k_codes[base..base + n] {
            *c = 0xFF;
        }
        for c in &mut self.v_codes[base..base + n] {
            *c = 0xFF;
        }
        let nan = UniformGroup { scale: f64::NAN, zero: f64::NAN };
        for g in &mut self.k_groups[ri0..ri0 + self.page_rows] {
            *g = nan;
        }
        for g in &mut self.v_groups[ri0..ri0 + self.page_rows] {
            *g = nan;
        }
    }
}

// ---------------------------------------------------------------------------
// the pool

/// Snapshot of a pool's accounting, for reports and benches.
#[derive(Debug, Clone, Copy)]
pub struct KvPoolStats {
    /// Pages backed by storage (== the cap for bounded pools).
    pub total_pages: usize,
    /// Pages on the free list right now.
    pub free_list: usize,
    /// Pages currently holding live rows.
    pub allocated: usize,
    /// Pages reserved by admitted sessions but not yet drawn.
    pub reserved: usize,
    /// High-water mark of `allocated`.
    pub peak_allocated: usize,
    /// Rows per page.
    pub page_rows: usize,
    /// Resident bytes of one page.
    pub page_bytes: usize,
    /// Which store backs the pages.
    pub kind: KvStoreKind,
}

/// The shared KV arena: fixed-size pages, a LIFO free list, per-page
/// owner tracking, and reservation-based admission. One pool per
/// engine, shared by every [`PagedKvCache`] through `Rc<RefCell<..>>`
/// (the engine is single-threaded; determinism forbids cross-thread
/// allocation order anyway).
///
/// Accounting invariant: `allocated + reserved ≤ max_pages` for bounded
/// pools, and for every live cache `pages_held + reservation` equals
/// the page count reserved at admission — so an admitted session can
/// always draw its next page without touching anyone else's budget.
pub struct KvPool {
    page_rows: usize,
    d_model: usize,
    n_layers: usize,
    /// 0 = unbounded (grow on demand)
    max_pages: usize,
    poison: bool,
    store: Box<dyn PageStore>,
    /// LIFO free list (bounded pools start fully populated)
    free: Vec<u32>,
    /// per-page owner token; [`FREE`] when unallocated
    owner: Vec<u64>,
    allocated: usize,
    reserved: usize,
    peak_allocated: usize,
    next_owner: u64,
}

impl std::fmt::Debug for KvPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("KvPool")
            .field("kind", &self.store.kind())
            .field("page_rows", &self.page_rows)
            .field("max_pages", &self.max_pages)
            .field("allocated", &self.allocated)
            .field("reserved", &self.reserved)
            .field("free", &self.free.len())
            .finish()
    }
}

impl KvPool {
    /// A pool for `cfg`'s geometry: pages of `page_rows` rows, capped at
    /// `max_pages` total (`0` = unbounded, grow on demand), rows stored
    /// per `kind`. Poison-fill of freed pages is on by default.
    pub fn new(cfg: &ModelConfig, page_rows: usize, max_pages: usize, kind: KvStoreKind) -> KvPool {
        let page_rows = page_rows.max(1);
        let store: Box<dyn PageStore> = match kind {
            KvStoreKind::F64Dense => Box::new(F64Dense::new(page_rows, cfg.d_model)),
            KvStoreKind::Int8Group => Box::new(Int8Group::new(page_rows, cfg.d_model)),
        };
        let mut pool = KvPool {
            page_rows,
            d_model: cfg.d_model,
            n_layers: cfg.n_layers,
            max_pages,
            poison: true,
            store,
            free: Vec::new(),
            owner: Vec::new(),
            allocated: 0,
            reserved: 0,
            peak_allocated: 0,
            next_owner: 0,
        };
        if max_pages > 0 {
            pool.store.grow_to(max_pages);
            pool.owner.resize(max_pages, FREE);
            // reversed so pages pop in 0, 1, 2, … order (determinism aid)
            pool.free.extend((0..max_pages as u32).rev());
        }
        pool
    }

    /// Shared handle form, as the engine holds it.
    pub fn shared(cfg: &ModelConfig, page_rows: usize, max_pages: usize, kind: KvStoreKind) -> Rc<RefCell<KvPool>> {
        Rc::new(RefCell::new(KvPool::new(cfg, page_rows, max_pages, kind)))
    }

    /// Rows per page.
    pub fn page_rows(&self) -> usize {
        self.page_rows
    }

    /// Pages a session holding up to `rows` positions needs — one page
    /// table per layer, each `ceil(rows / page_rows)` pages.
    pub fn pages_for_rows(&self, rows: usize) -> usize {
        self.n_layers * rows.div_ceil(self.page_rows)
    }

    /// Arena capacity in pages; `usize::MAX` when unbounded.
    pub fn capacity_pages(&self) -> usize {
        if self.max_pages == 0 {
            usize::MAX
        } else {
            self.max_pages
        }
    }

    /// Pages neither allocated nor reserved; `usize::MAX` when unbounded.
    pub fn free_pages(&self) -> usize {
        if self.max_pages == 0 {
            usize::MAX
        } else {
            self.max_pages - self.allocated - self.reserved
        }
    }

    /// Toggle poison-filling of freed pages (on by default; benches turn
    /// it off to time the steady state).
    pub fn set_poison(&mut self, on: bool) {
        self.poison = on;
    }

    /// Reserve the worst-case page count for a session of up to
    /// `max_rows` positions. Returns the owner token and the reserved
    /// page count, or `None` when the arena cannot fit it — the
    /// `KvExhausted` shed path.
    pub fn admit(&mut self, max_rows: usize) -> Option<(u64, usize)> {
        let need = self.pages_for_rows(max_rows);
        if self.max_pages > 0 && self.max_pages - self.allocated - self.reserved < need {
            return None;
        }
        self.reserved += need;
        let owner = self.next_owner;
        self.next_owner += 1;
        Some((owner, need))
    }

    /// Draw one page from `owner`'s reservation. The reservation
    /// invariant guarantees a bounded pool's free list is non-empty
    /// here; unbounded pools grow the arena on demand.
    fn alloc_page(&mut self, owner: u64) -> u32 {
        assert!(self.reserved > 0, "alloc_page without a reservation");
        if self.free.is_empty() {
            debug_assert_eq!(self.max_pages, 0, "bounded free list exhausted under reservation");
            let cur = self.store.n_pages();
            self.store.grow_to(cur + GROW_CHUNK);
            self.owner.resize(cur + GROW_CHUNK, FREE);
            for p in ((cur as u32)..(cur + GROW_CHUNK) as u32).rev() {
                self.free.push(p);
            }
        }
        let Some(page) = self.free.pop() else {
            unreachable!("free list refilled above")
        };
        self.owner[page as usize] = owner;
        self.allocated += 1;
        self.reserved -= 1;
        self.peak_allocated = self.peak_allocated.max(self.allocated);
        page
    }

    /// Return a page to the free list *and* to `owner`'s reservation —
    /// the truncate/clear path, where the session may grow again.
    fn release_page(&mut self, owner: u64, page: u32) {
        self.retire_page(owner, page);
        self.reserved += 1;
    }

    /// Return a page to the free list without re-reserving — the
    /// session-retirement path.
    fn free_page_terminal(&mut self, owner: u64, page: u32) {
        self.retire_page(owner, page);
    }

    fn retire_page(&mut self, owner: u64, page: u32) {
        let idx = page as usize;
        assert_eq!(self.owner[idx], owner, "page {page} released by a non-owner");
        if self.poison {
            self.store.poison(page);
        }
        self.owner[idx] = FREE;
        self.free.push(page);
        self.allocated -= 1;
    }

    /// Give back `n` reserved-but-undrawn pages (session retirement).
    fn release_reservation(&mut self, n: usize) {
        debug_assert!(n <= self.reserved);
        self.reserved -= n;
    }

    /// Cross-check the arena's books: owner map vs free list vs
    /// counters. Used by the randomized reuse tests; `Err` carries the
    /// first inconsistency found.
    pub fn verify_integrity(&self) -> Result<(), String> {
        let n = self.store.n_pages();
        if self.owner.len() != n {
            return Err(format!("owner map {} != {} backed pages", self.owner.len(), n));
        }
        if self.max_pages > 0 && n != self.max_pages {
            return Err(format!("bounded pool backs {n} pages, cap {}", self.max_pages));
        }
        let live = self.owner.iter().filter(|&&o| o != FREE).count();
        if live != self.allocated {
            return Err(format!("{live} owned pages but allocated = {}", self.allocated));
        }
        if self.free.len() + self.allocated != n {
            return Err(format!(
                "free {} + allocated {} != {n} pages",
                self.free.len(),
                self.allocated
            ));
        }
        let mut seen = vec![false; n];
        for &p in &self.free {
            let i = p as usize;
            if i >= n {
                return Err(format!("free-list page {p} out of range"));
            }
            if seen[i] {
                return Err(format!("page {p} is on the free list twice"));
            }
            seen[i] = true;
            if self.owner[i] != FREE {
                return Err(format!("free-list page {p} still owned by {}", self.owner[i]));
            }
        }
        if self.max_pages > 0 && self.allocated + self.reserved > self.max_pages {
            return Err(format!(
                "allocated {} + reserved {} exceeds cap {}",
                self.allocated, self.reserved, self.max_pages
            ));
        }
        Ok(())
    }

    /// Accounting snapshot.
    pub fn stats(&self) -> KvPoolStats {
        KvPoolStats {
            total_pages: self.store.n_pages(),
            free_list: self.free.len(),
            allocated: self.allocated,
            reserved: self.reserved,
            peak_allocated: self.peak_allocated,
            page_rows: self.page_rows,
            page_bytes: self.store.page_bytes(),
            kind: self.store.kind(),
        }
    }
}

// ---------------------------------------------------------------------------
// the per-session handle

/// A session's view of the pool: page tables instead of buffers. Keeps
/// the full [`KvSeq`] contract of the contiguous cache — including
/// `truncate` rollback for speculative decode — but `truncate`/`clear`
/// return whole pages to the free list, and dropping the handle returns
/// everything (pages *and* unspent reservation).
pub struct PagedKvCache {
    pool: Rc<RefCell<KvPool>>,
    owner: u64,
    /// one page table per layer
    tables: Vec<Vec<u32>>,
    /// staged rows per layer (run ahead of `len` mid-forward)
    rows: Vec<usize>,
    len: usize,
    /// pages reserved at admission and not yet drawn
    reservation: usize,
    max_rows: usize,
    page_rows: usize,
    d: usize,
    page_bytes: usize,
    scratch_k: Vec<f64>,
    scratch_v: Vec<f64>,
}

impl std::fmt::Debug for PagedKvCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PagedKvCache")
            .field("owner", &self.owner)
            .field("len", &self.len)
            .field("max_rows", &self.max_rows)
            .field("pages_held", &self.pages_held())
            .field("reservation", &self.reservation)
            .finish()
    }
}

impl PagedKvCache {
    /// Admit a session of up to `max_rows` positions against `pool`,
    /// reserving its worst-case page count. `None` when the arena
    /// cannot fit it (the caller sheds with `KvExhausted`).
    pub fn new(pool: &Rc<RefCell<KvPool>>, max_rows: usize) -> Option<PagedKvCache> {
        let (owner, need, page_rows, d, n_layers, page_bytes) = {
            let mut p = pool.borrow_mut();
            let (owner, need) = p.admit(max_rows)?;
            (owner, need, p.page_rows, p.d_model, p.n_layers, p.store.page_bytes())
        };
        Some(PagedKvCache {
            pool: Rc::clone(pool),
            owner,
            tables: (0..n_layers).map(|_| Vec::new()).collect(),
            rows: vec![0; n_layers],
            len: 0,
            reservation: need,
            max_rows,
            page_rows,
            d,
            page_bytes,
            scratch_k: vec![0.0; d],
            scratch_v: vec![0.0; d],
        })
    }

    /// This session's owner token in the pool (unique per admission).
    pub fn owner_id(&self) -> u64 {
        self.owner
    }

    /// Pages currently held across all layers.
    pub fn pages_held(&self) -> usize {
        self.tables.iter().map(Vec::len).sum()
    }
}

impl KvSeq for PagedKvCache {
    fn len(&self) -> usize {
        self.len
    }

    fn n_layers(&self) -> usize {
        self.tables.len()
    }

    fn clear(&mut self) {
        let mut pool = self.pool.borrow_mut();
        for (l, table) in self.tables.iter_mut().enumerate() {
            while let Some(page) = table.pop() {
                pool.release_page(self.owner, page);
                self.reservation += 1;
            }
            self.rows[l] = 0;
        }
        self.len = 0;
    }

    fn truncate(&mut self, n: usize) {
        if n >= self.len {
            return;
        }
        let keep = n.div_ceil(self.page_rows);
        let mut pool = self.pool.borrow_mut();
        for (l, table) in self.tables.iter_mut().enumerate() {
            debug_assert_eq!(self.rows[l], self.len, "layer {l} mid-forward");
            while table.len() > keep {
                let Some(page) = table.pop() else {
                    unreachable!("table len checked above")
                };
                pool.release_page(self.owner, page);
                self.reservation += 1;
            }
            self.rows[l] = n;
        }
        // rows n.. of the kept partial page are stale but unreachable:
        // every read is bounded by `len`, and re-appends overwrite them
        self.len = n;
    }

    fn append_rows(&mut self, layer: usize, k: &[f64], v: &[f64]) {
        debug_assert_eq!(k.len() % self.d, 0);
        debug_assert_eq!(k.len(), v.len());
        let n = k.len() / self.d;
        let staged = self.rows[layer];
        debug_assert_eq!(staged, self.len, "layer {layer} appended twice");
        assert!(
            staged + n <= self.max_rows,
            "paged cache overflow: {staged} + {n} rows > admitted max {}",
            self.max_rows
        );
        let table = &mut self.tables[layer];
        let mut pool = self.pool.borrow_mut();
        for i in 0..n {
            let row = staged + i;
            let (pi, slot) = (row / self.page_rows, row % self.page_rows);
            if pi == table.len() {
                debug_assert!(self.reservation > 0, "reservation exhausted before max_rows");
                table.push(pool.alloc_page(self.owner));
                self.reservation -= 1;
            }
            pool.store.write_row(
                table[pi],
                slot,
                &k[i * self.d..(i + 1) * self.d],
                &v[i * self.d..(i + 1) * self.d],
            );
        }
        self.rows[layer] = staged + n;
    }

    fn advance(&mut self, n: usize) {
        self.len += n;
        for (li, r) in self.rows.iter().enumerate() {
            debug_assert_eq!(*r, self.len, "layer {li} out of sync");
        }
    }

    fn memory_bytes(&self) -> usize {
        self.pages_held() * self.page_bytes
    }

    fn k_row(&mut self, layer: usize, row: usize) -> &[f64] {
        debug_assert!(row < self.rows[layer], "k_row past staged rows");
        let page = self.tables[layer][row / self.page_rows];
        self.pool.borrow().store.read_k_row(page, row % self.page_rows, &mut self.scratch_k);
        &self.scratch_k
    }

    fn v_row(&mut self, layer: usize, row: usize) -> &[f64] {
        debug_assert!(row < self.rows[layer], "v_row past staged rows");
        let page = self.tables[layer][row / self.page_rows];
        self.pool.borrow().store.read_v_row(page, row % self.page_rows, &mut self.scratch_v);
        &self.scratch_v
    }
}

impl Drop for PagedKvCache {
    fn drop(&mut self) {
        // try_borrow_mut: a drop during an unwind that holds the pool
        // borrowed must not double-panic; leaking pages on that path is
        // acceptable (the process is going down anyway)
        if let Ok(mut pool) = self.pool.try_borrow_mut() {
            for table in &mut self.tables {
                while let Some(page) = table.pop() {
                    pool.free_page_terminal(self.owner, page);
                }
            }
            pool.release_reservation(self.reservation);
            self.reservation = 0;
        }
    }
}

// ---------------------------------------------------------------------------
// the engine-facing backing enum

/// What backs one slot's KV: the contiguous oracle cache (no pool
/// configured) or a paged handle. The engine stores this so both paths
/// run the identical generic forward.
pub enum KvBacking {
    /// Contiguous per-session cache (the unpooled default and the
    /// parity oracle).
    Contiguous(KvCache),
    /// Page-table handle over the engine's shared [`KvPool`].
    Paged(PagedKvCache),
}

impl KvBacking {
    /// The unpooled default backing.
    pub fn contiguous(cfg: &ModelConfig) -> KvBacking {
        KvBacking::Contiguous(KvCache::oracle(cfg))
    }

    /// True for the paged variant.
    pub fn is_paged(&self) -> bool {
        matches!(self, KvBacking::Paged(_))
    }
}

impl KvSeq for KvBacking {
    fn len(&self) -> usize {
        match self {
            KvBacking::Contiguous(c) => KvSeq::len(c),
            KvBacking::Paged(p) => p.len(),
        }
    }
    fn n_layers(&self) -> usize {
        match self {
            KvBacking::Contiguous(c) => KvSeq::n_layers(c),
            KvBacking::Paged(p) => KvSeq::n_layers(p),
        }
    }
    fn clear(&mut self) {
        match self {
            KvBacking::Contiguous(c) => c.clear(),
            KvBacking::Paged(p) => KvSeq::clear(p),
        }
    }
    fn truncate(&mut self, n: usize) {
        match self {
            KvBacking::Contiguous(c) => c.truncate(n),
            KvBacking::Paged(p) => KvSeq::truncate(p, n),
        }
    }
    fn append_rows(&mut self, layer: usize, k: &[f64], v: &[f64]) {
        match self {
            KvBacking::Contiguous(c) => c.append_rows(layer, k, v),
            KvBacking::Paged(p) => KvSeq::append_rows(p, layer, k, v),
        }
    }
    fn advance(&mut self, n: usize) {
        match self {
            KvBacking::Contiguous(c) => c.advance(n),
            KvBacking::Paged(p) => KvSeq::advance(p, n),
        }
    }
    fn memory_bytes(&self) -> usize {
        match self {
            KvBacking::Contiguous(c) => c.memory_bytes(),
            KvBacking::Paged(p) => KvSeq::memory_bytes(p),
        }
    }
    fn k_row(&mut self, layer: usize, row: usize) -> &[f64] {
        match self {
            KvBacking::Contiguous(c) => c.k_row(layer, row),
            KvBacking::Paged(p) => p.k_row(layer, row),
        }
    }
    fn v_row(&mut self, layer: usize, row: usize) -> &[f64] {
        match self {
            KvBacking::Contiguous(c) => c.v_row(layer, row),
            KvBacking::Paged(p) => p.v_row(layer, row),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::forward::tests::tiny_model;
    use crate::model::forward::{forward_logits_cached, nll_from_logits};

    fn assert_bitwise(a: &[f64], b: &[f64], what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: element {i} ({x} vs {y})");
        }
    }

    #[test]
    fn paged_dense_is_bitwise_identical_to_oracle_across_page_sizes() {
        // the tentpole parity pin: prefill, incremental decode, and the
        // speculative truncate-rollback all bitwise-match the contiguous
        // oracle at every required page size
        let m = tiny_model(81);
        let toks: Vec<u8> = (0..16).map(|i| (i * 37 + 11) as u8).collect();
        let rejects: Vec<u8> = vec![250, 251, 252];
        for page_rows in [1usize, 3, 8, 64] {
            let pool = KvPool::shared(&m.cfg, page_rows, 0, KvStoreKind::F64Dense);
            let mut paged = PagedKvCache::new(&pool, 32).expect("unbounded admit");
            let mut oracle = KvCache::oracle(&m.cfg);

            // prefill
            let lp = forward_logits_cached(&m, &mut paged, &toks[..8]);
            let lo = forward_logits_cached(&m, &mut oracle, &toks[..8]);
            assert_bitwise(lp.as_slice(), lo.as_slice(), "prefill");

            // speculative overshoot + rollback
            forward_logits_cached(&m, &mut paged, &rejects);
            forward_logits_cached(&m, &mut oracle, &rejects);
            KvSeq::truncate(&mut paged, 8);
            oracle.truncate(8);
            assert_eq!(KvSeq::len(&paged), 8);

            // incremental decode to the end
            for t in 8..toks.len() {
                let lp = forward_logits_cached(&m, &mut paged, &toks[t..t + 1]);
                let lo = forward_logits_cached(&m, &mut oracle, &toks[t..t + 1]);
                assert_bitwise(lp.as_slice(), lo.as_slice(), "decode step");
            }
            assert_eq!(KvSeq::len(&paged), oracle.len());
            drop(paged);
            let p = pool.borrow();
            p.verify_integrity().expect("books balance after drop");
            assert_eq!(p.stats().allocated, 0, "pages leaked at page_rows={page_rows}");
        }
    }

    #[test]
    fn int8_paged_drift_stays_within_the_documented_bound() {
        // perplexity-proxy guardrail: mean NLL through the int8 paged
        // cache stays within KV_INT8_NLL_REL_TOL of the f64 oracle
        let m = tiny_model(82);
        let toks: Vec<u8> = (0..24).map(|i| (i * 13 + 7) as u8).collect();
        let mut oracle = KvCache::oracle(&m.cfg);
        let lo = forward_logits_cached(&m, &mut oracle, &toks);
        let nll_o = nll_from_logits(&lo, &toks);
        let pool = KvPool::shared(&m.cfg, 8, 0, KvStoreKind::Int8Group);
        let mut paged = PagedKvCache::new(&pool, 32).expect("unbounded admit");
        let lq = forward_logits_cached(&m, &mut paged, &toks);
        let nll_q = nll_from_logits(&lq, &toks);
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        let (mo, mq) = (mean(&nll_o), mean(&nll_q));
        let rel = (mq - mo).abs() / mo.abs().max(1e-12);
        assert!(
            rel <= KV_INT8_NLL_REL_TOL,
            "int8 KV drift {rel:.4} exceeds tolerance {KV_INT8_NLL_REL_TOL} (nll {mo:.4} -> {mq:.4})"
        );
        assert!(lq.as_slice().iter().all(|v| v.is_finite()), "int8 path produced non-finite logits");
    }

    #[test]
    fn int8_pages_are_4x_denser_than_f64() {
        let m = tiny_model(83);
        let f64_pool = KvPool::new(&m.cfg, 8, 0, KvStoreKind::F64Dense);
        let int8_pool = KvPool::new(&m.cfg, 8, 0, KvStoreKind::Int8Group);
        let (fb, ib) = (f64_pool.stats().page_bytes, int8_pool.stats().page_bytes);
        assert!(ib * 4 <= fb, "int8 page {ib} B not 4x denser than f64 page {fb} B");
    }

    #[test]
    fn admission_reserves_and_refuses_when_the_arena_is_full() {
        // demo geometry: 2 layers. page_rows 4, cap 8 pages.
        let m = tiny_model(84);
        let pool = KvPool::shared(&m.cfg, 4, 8, KvStoreKind::F64Dense);
        assert_eq!(pool.borrow().pages_for_rows(8), 4); // 2 layers × 2 pages
        let a = PagedKvCache::new(&pool, 8).expect("first session fits");
        assert_eq!(pool.borrow().free_pages(), 4);
        // a 16-row session needs 8 pages; only 4 are uncommitted
        assert!(PagedKvCache::new(&pool, 16).is_none(), "over-admission");
        let b = PagedKvCache::new(&pool, 8).expect("second 8-row session fits");
        assert_eq!(pool.borrow().free_pages(), 0);
        assert!(PagedKvCache::new(&pool, 1).is_none(), "arena fully reserved");
        drop(a);
        drop(b);
        let p = pool.borrow();
        assert_eq!(p.free_pages(), 8, "free list did not balance to the full arena");
        p.verify_integrity().expect("books balance");
    }

    #[test]
    fn truncate_and_clear_return_pages_to_the_free_list() {
        let m = tiny_model(85);
        let pool = KvPool::shared(&m.cfg, 2, 8, KvStoreKind::F64Dense);
        let mut c = PagedKvCache::new(&pool, 8).expect("admit");
        let d = m.cfg.d_model;
        let row: Vec<f64> = (0..d).map(|i| i as f64 * 0.25 + 1.0).collect();
        // commit 6 rows one position at a time (the forward protocol:
        // append every layer, then advance) — walks page boundaries
        for _ in 0..6 {
            c.append_rows(0, &row, &row);
            c.append_rows(1, &row, &row);
            c.advance(1);
        }
        assert_eq!(c.pages_held(), 6); // 3 pages × 2 layers
        assert_eq!(KvSeq::memory_bytes(&c), 6 * pool.borrow().stats().page_bytes);
        KvSeq::truncate(&mut c, 3);
        // ceil(3/2) = 2 pages per layer survive
        assert_eq!(c.pages_held(), 4);
        assert_eq!(pool.borrow().stats().allocated, 4);
        // rows 0..3 still read back exactly
        for layer in 0..2 {
            for r in 0..3 {
                assert_eq!(c.k_row(layer, r), &row[..]);
            }
        }
        KvSeq::clear(&mut c);
        assert_eq!(c.pages_held(), 0);
        assert_eq!(pool.borrow().stats().allocated, 0);
        // reservation survived: the session can refill after clear
        c.append_rows(0, &row, &row);
        c.append_rows(1, &row, &row);
        c.advance(1);
        assert_eq!(KvSeq::len(&c), 1);
        drop(c);
        pool.borrow().verify_integrity().expect("books balance");
        assert_eq!(pool.borrow().free_pages(), 8);
    }

    #[test]
    fn freed_pages_are_poisoned() {
        let m = tiny_model(86);
        for kind in [KvStoreKind::F64Dense, KvStoreKind::Int8Group] {
            let pool = KvPool::shared(&m.cfg, 2, 4, kind);
            let mut c = PagedKvCache::new(&pool, 4).expect("admit");
            let d = m.cfg.d_model;
            let row: Vec<f64> = (0..d).map(|i| (i as f64).sin()).collect();
            c.append_rows(0, &row, &row);
            c.append_rows(1, &row, &row);
            c.advance(1);
            let page = c.tables[0][0];
            drop(c); // frees + poisons
            let mut out = vec![0.0f64; d];
            pool.borrow().store.read_k_row(page, 0, &mut out);
            assert!(
                out.iter().all(|v| v.is_nan()),
                "{kind:?}: freed page not poisoned ({out:?})"
            );
            // a fresh session reusing the page overwrites the poison
            let mut c2 = PagedKvCache::new(&pool, 4).expect("re-admit");
            c2.append_rows(0, &row, &row);
            c2.append_rows(1, &row, &row);
            c2.advance(1);
            let k = c2.k_row(0, 0).to_vec();
            assert!(k.iter().all(|v| v.is_finite()), "{kind:?}: poison leaked into live rows");
        }
    }

    #[test]
    fn unbounded_pool_grows_on_demand() {
        let m = tiny_model(87);
        let pool = KvPool::shared(&m.cfg, 1, 0, KvStoreKind::F64Dense);
        assert_eq!(pool.borrow().free_pages(), usize::MAX);
        let mut c = PagedKvCache::new(&pool, 64).expect("unbounded admit never refuses");
        let d = m.cfg.d_model;
        let row = vec![1.0f64; d];
        for _ in 0..20 {
            c.append_rows(0, &row, &row);
            c.append_rows(1, &row, &row);
            c.advance(1);
        }
        assert_eq!(c.pages_held(), 40);
        assert!(pool.borrow().stats().total_pages >= 40);
        pool.borrow().verify_integrity().expect("books balance while live");
        drop(c);
        pool.borrow().verify_integrity().expect("books balance after drop");
        assert_eq!(pool.borrow().stats().allocated, 0);
    }

    #[test]
    fn kv_backing_dispatches_both_variants() {
        let m = tiny_model(88);
        let toks: Vec<u8> = (0..10).map(|i| (i * 7 + 5) as u8).collect();
        let mut a = KvBacking::contiguous(&m.cfg);
        assert!(!a.is_paged());
        let la = forward_logits_cached(&m, &mut a, &toks);
        let pool = KvPool::shared(&m.cfg, 3, 0, KvStoreKind::F64Dense);
        let mut b = KvBacking::Paged(PagedKvCache::new(&pool, 32).expect("admit"));
        assert!(b.is_paged());
        let lb = forward_logits_cached(&m, &mut b, &toks);
        assert_bitwise(la.as_slice(), lb.as_slice(), "backing parity");
        assert_eq!(KvSeq::len(&a), KvSeq::len(&b));
        assert!(KvSeq::memory_bytes(&b) > 0);
    }
}
