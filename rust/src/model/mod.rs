//! The evaluation-substrate model: a Llama-architecture byte LM whose
//! weights are trained in JAX at build time (`python/compile/train.py`)
//! and loaded here from the GVQCKPT1 checkpoint.
//!
//! `forward.rs` is the native rust forward pass — numerically mirrored
//! against the JAX/L2 definition (cross-checked by integration tests via
//! the AOT HLO artifacts). It serves two jobs on the quantization path:
//! calibration-activation capture (Hessian accumulation) and perplexity /
//! zero-shot evaluation of quantized checkpoints.

pub mod checkpoint;
pub mod forward;
pub mod kv;
pub mod kvpool;

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Error, Result};
use crate::tensor::Matrix;

/// Model hyperparameters, parsed from the `.meta` key=value file written
/// at training time.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ffn: usize,
    pub max_seq: usize,
    pub rope_theta: f64,
    pub norm_eps: f64,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// The tests' tiny geometry with a chosen context length — the demo
    /// scale used by serving benches when no trained artifacts exist.
    pub fn demo(max_seq: usize) -> ModelConfig {
        ModelConfig {
            vocab: 256,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ffn: 24,
            max_seq,
            rope_theta: 10000.0,
            norm_eps: 1e-5,
        }
    }

    /// Parse the `key=value` .meta file.
    pub fn from_meta_file(path: impl AsRef<Path>) -> Result<ModelConfig> {
        let text = std::fs::read_to_string(path.as_ref())?;
        let mut kv = BTreeMap::new();
        for line in text.lines() {
            if let Some((k, v)) = line.split_once('=') {
                kv.insert(k.trim().to_string(), v.trim().to_string());
            }
        }
        let get = |k: &str| -> Result<String> {
            kv.get(k)
                .cloned()
                .ok_or_else(|| Error::format(path.as_ref().display().to_string(), format!("missing key {k}")))
        };
        let parse_usize = |k: &str| -> Result<usize> {
            get(k)?.parse().map_err(|e| Error::msg(format!("bad {k}: {e}")))
        };
        let parse_f64 = |k: &str| -> Result<f64> {
            get(k)?.parse().map_err(|e| Error::msg(format!("bad {k}: {e}")))
        };
        Ok(ModelConfig {
            vocab: parse_usize("vocab")?,
            d_model: parse_usize("d_model")?,
            n_layers: parse_usize("n_layers")?,
            n_heads: parse_usize("n_heads")?,
            d_ffn: parse_usize("d_ffn")?,
            max_seq: parse_usize("max_seq")?,
            rope_theta: parse_f64("rope_theta")?,
            norm_eps: parse_f64("norm_eps")?,
        })
    }
}

/// A linear layer's role inside a block — used to locate quantization
/// targets and to route captured activations.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LinearKind {
    Wq,
    Wk,
    Wv,
    Wo,
    WGate,
    WUp,
    WDown,
}

impl LinearKind {
    pub const ALL: [LinearKind; 7] = [
        LinearKind::Wq,
        LinearKind::Wk,
        LinearKind::Wv,
        LinearKind::Wo,
        LinearKind::WGate,
        LinearKind::WUp,
        LinearKind::WDown,
    ];

    pub fn suffix(self) -> &'static str {
        match self {
            LinearKind::Wq => "attn.wq",
            LinearKind::Wk => "attn.wk",
            LinearKind::Wv => "attn.wv",
            LinearKind::Wo => "attn.wo",
            LinearKind::WGate => "ffn.w_gate",
            LinearKind::WUp => "ffn.w_up",
            LinearKind::WDown => "ffn.w_down",
        }
    }
}

/// Fully materialized model: weights as f64 matrices in the **storage
/// layout** `[in, out]` (`y = x @ W`), norms as vectors.
#[derive(Debug, Clone)]
pub struct Model {
    pub cfg: ModelConfig,
    /// embed [vocab, d_model]
    pub embed: Matrix,
    pub layers: Vec<LayerWeights>,
    pub final_norm: Vec<f64>,
    /// head [d_model, vocab]
    pub head: Matrix,
}

#[derive(Debug, Clone)]
pub struct LayerWeights {
    pub ln_attn: Vec<f64>,
    pub wq: Matrix,
    pub wk: Matrix,
    pub wv: Matrix,
    pub wo: Matrix,
    pub ln_ffn: Vec<f64>,
    pub w_gate: Matrix,
    pub w_up: Matrix,
    pub w_down: Matrix,
}

impl Model {
    /// Load model weights + config from `artifacts/model_<preset>.{ckpt,meta}`.
    pub fn load(artifacts_dir: impl AsRef<Path>, preset: &str) -> Result<Model> {
        let dir = artifacts_dir.as_ref();
        let cfg = ModelConfig::from_meta_file(dir.join(format!("model_{preset}.meta")))?;
        let ck = checkpoint::load(dir.join(format!("model_{preset}.ckpt")))?;
        Model::from_checkpoint(cfg, &ck)
    }

    pub fn from_checkpoint(cfg: ModelConfig, ck: &checkpoint::Checkpoint) -> Result<Model> {
        let mat = |name: &str| -> Result<Matrix> {
            let t = ck.get(name).ok_or_else(|| Error::msg(format!("missing tensor {name}")))?;
            if t.shape.len() != 2 {
                return Err(Error::Shape(format!("{name}: expected 2-d, got {:?}", t.shape)));
            }
            Matrix::from_f32(t.shape[0], t.shape[1], t.as_f32()?)
        };
        let vec = |name: &str| -> Result<Vec<f64>> {
            let t = ck.get(name).ok_or_else(|| Error::msg(format!("missing tensor {name}")))?;
            Ok(t.as_f32()?.iter().map(|&x| x as f64).collect())
        };
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for i in 0..cfg.n_layers {
            let p = format!("layers.{i}.");
            layers.push(LayerWeights {
                ln_attn: vec(&format!("{p}ln_attn"))?,
                wq: mat(&format!("{p}attn.wq"))?,
                wk: mat(&format!("{p}attn.wk"))?,
                wv: mat(&format!("{p}attn.wv"))?,
                wo: mat(&format!("{p}attn.wo"))?,
                ln_ffn: vec(&format!("{p}ln_ffn"))?,
                w_gate: mat(&format!("{p}ffn.w_gate"))?,
                w_up: mat(&format!("{p}ffn.w_up"))?,
                w_down: mat(&format!("{p}ffn.w_down"))?,
            });
        }
        Ok(Model {
            embed: mat("embed")?,
            layers,
            final_norm: vec("final_norm")?,
            head: mat("head")?,
            cfg,
        })
    }

    /// Build a synthetic random-weight model (benches and demos without
    /// trained artifacts; also the tests' `tiny_model`). Deterministic in
    /// `seed`.
    pub fn synthetic(cfg: ModelConfig, seed: u64) -> Model {
        use crate::util::Rng;
        let d = cfg.d_model;
        let mut rng = Rng::new(seed);
        let mut randm = |r: usize, c: usize| Matrix::from_fn(r, c, |_, _| rng.gaussian() * 0.1);
        let layers: Vec<LayerWeights> = (0..cfg.n_layers)
            .map(|_| LayerWeights {
                ln_attn: vec![1.0; d],
                wq: randm(d, d),
                wk: randm(d, d),
                wv: randm(d, d),
                wo: randm(d, d),
                ln_ffn: vec![1.0; d],
                w_gate: randm(d, cfg.d_ffn),
                w_up: randm(d, cfg.d_ffn),
                w_down: randm(cfg.d_ffn, d),
            })
            .collect();
        let head = randm(d, cfg.vocab);
        let mut erng = Rng::new(seed ^ 0x5EED);
        let embed = Matrix::from_fn(cfg.vocab, d, |_, _| erng.gaussian() * 0.1);
        Model { embed, layers, final_norm: vec![1.0; d], head, cfg }
    }

    /// Name of a quantizable linear (matches the checkpoint schema).
    pub fn linear_name(layer: usize, kind: LinearKind) -> String {
        format!("layers.{layer}.{}", kind.suffix())
    }

    /// Borrow a quantizable linear's weight (storage layout [in, out]).
    pub fn linear(&self, layer: usize, kind: LinearKind) -> &Matrix {
        let l = &self.layers[layer];
        match kind {
            LinearKind::Wq => &l.wq,
            LinearKind::Wk => &l.wk,
            LinearKind::Wv => &l.wv,
            LinearKind::Wo => &l.wo,
            LinearKind::WGate => &l.w_gate,
            LinearKind::WUp => &l.w_up,
            LinearKind::WDown => &l.w_down,
        }
    }

    /// Replace a quantizable linear's weight.
    pub fn set_linear(&mut self, layer: usize, kind: LinearKind, w: Matrix) {
        let l = &mut self.layers[layer];
        let slot = match kind {
            LinearKind::Wq => &mut l.wq,
            LinearKind::Wk => &mut l.wk,
            LinearKind::Wv => &mut l.wv,
            LinearKind::Wo => &mut l.wo,
            LinearKind::WGate => &mut l.w_gate,
            LinearKind::WUp => &mut l.w_up,
            LinearKind::WDown => &mut l.w_down,
        };
        assert_eq!((slot.rows(), slot.cols()), (w.rows(), w.cols()), "shape change");
        *slot = w;
    }

    /// Drop a linear's dense storage (replaced by an empty matrix) —
    /// used by serving backends that execute the linear from a packed
    /// container and must not keep the f64 copy resident.
    pub fn clear_linear(&mut self, layer: usize, kind: LinearKind) {
        let l = &mut self.layers[layer];
        let slot = match kind {
            LinearKind::Wq => &mut l.wq,
            LinearKind::Wk => &mut l.wk,
            LinearKind::Wv => &mut l.wv,
            LinearKind::Wo => &mut l.wo,
            LinearKind::WGate => &mut l.w_gate,
            LinearKind::WUp => &mut l.w_up,
            LinearKind::WDown => &mut l.w_down,
        };
        *slot = Matrix::zeros(0, 0);
    }

    /// All (layer, kind) quantization targets in forward order.
    pub fn quant_targets(&self) -> Vec<(usize, LinearKind)> {
        let mut out = Vec::new();
        for i in 0..self.cfg.n_layers {
            for kind in LinearKind::ALL {
                out.push((i, kind));
            }
        }
        out
    }

    /// Total quantizable weight count.
    pub fn quantizable_weights(&self) -> usize {
        self.quant_targets()
            .iter()
            .map(|&(l, k)| {
                let m = self.linear(l, k);
                m.rows() * m.cols()
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta_text() -> &'static str {
        "vocab=256\nd_model=32\nn_layers=2\nn_heads=2\nd_ffn=64\nmax_seq=16\nrope_theta=10000.0\nnorm_eps=1e-05\npreset=test\n"
    }

    #[test]
    fn parses_meta() {
        let p = std::env::temp_dir().join(format!("gptvq_meta_{}", std::process::id()));
        std::fs::write(&p, meta_text()).unwrap();
        let cfg = ModelConfig::from_meta_file(&p).unwrap();
        assert_eq!(cfg.d_model, 32);
        assert_eq!(cfg.head_dim(), 16);
        assert_eq!(cfg.norm_eps, 1e-5);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn meta_missing_key_errors() {
        let p = std::env::temp_dir().join(format!("gptvq_meta_bad_{}", std::process::id()));
        std::fs::write(&p, "vocab=256\n").unwrap();
        assert!(ModelConfig::from_meta_file(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn quant_target_enumeration() {
        // names line up with the checkpoint schema
        assert_eq!(Model::linear_name(0, LinearKind::Wq), "layers.0.attn.wq");
        assert_eq!(Model::linear_name(3, LinearKind::WDown), "layers.3.ffn.w_down");
    }

    #[test]
    fn loads_trained_artifacts_if_present() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("model_tiny.ckpt").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let model = Model::load(&dir, "tiny").unwrap();
        assert_eq!(model.cfg.vocab, 256);
        assert_eq!(model.layers.len(), model.cfg.n_layers);
        assert_eq!(model.embed.rows(), 256);
        assert_eq!(model.quant_targets().len(), model.cfg.n_layers * 7);
        assert!(model.quantizable_weights() > 0);
        // wq is [d_model, d_model] in storage layout
        assert_eq!(model.layers[0].wq.rows(), model.cfg.d_model);
    }
}
