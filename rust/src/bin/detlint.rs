//! `detlint` — determinism/robustness linter for the gptvq crate.
//!
//! Scans the crate's source trees and flags the hazard patterns that
//! break the bitwise-determinism contract; see `gptvq::util::detlint`
//! for the rule set and waiver policy, and `docs/ARCHITECTURE.md`
//! § "Verifying the determinism contract" for how this layer relates
//! to loom/Miri/TSan.
//!
//! ```text
//! usage: detlint [--json] [--strict-precision] [--manifest PATH] [ROOT...]
//! ```
//!
//! With no `ROOT`s, scans this crate's `src/` (full rule set), plus
//! `tests/`, `benches/`, and `../examples/` with the budget, clock, and
//! precision rules relaxed; the module-graph pass then checks the
//! `src/` dependency edges against `detlint_layers.toml` (override with
//! `--manifest`; the graph pass is skipped when no manifest exists,
//! e.g. when pointing detlint at an arbitrary tree). Explicit `ROOT`s
//! infer their kind from the path (`tests`/`benches`/`examples`
//! components relax the rules).
//!
//! Exits 0 when every scanned file is clean (waivers included), 1 on
//! any violation, 2 on I/O errors. The final text line
//! (`detlint: N violation(s), M waiver(s), F file(s) scanned`) is
//! stable for CI grepping; per-rule count lines precede it; `--json`
//! emits the whole report machine-readably, always listing every rule.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use gptvq::util::detlint::{graph, lint_tree_with, FileKind, LintOptions, LintReport};

/// Infer the tree kind from path components.
fn kind_of(root: &Path) -> FileKind {
    for comp in root.components() {
        let c = comp.as_os_str().to_string_lossy();
        match c.as_ref() {
            "tests" => return FileKind::Test,
            "benches" => return FileKind::Bench,
            "examples" => return FileKind::Example,
            _ => {}
        }
    }
    FileKind::Lib
}

fn main() -> ExitCode {
    let mut json = false;
    let mut strict_precision = false;
    let mut manifest_path: Option<PathBuf> = None;
    let mut roots: Vec<PathBuf> = Vec::new();
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--json" => json = true,
            "--strict-precision" => strict_precision = true,
            "--manifest" => match args.next() {
                Some(p) => manifest_path = Some(PathBuf::from(p)),
                None => {
                    eprintln!("detlint: --manifest requires a path");
                    return ExitCode::from(2);
                }
            },
            "--help" | "-h" => {
                println!("usage: detlint [--json] [--strict-precision] [--manifest PATH] [ROOT...]");
                println!("lints rust sources for determinism hazards; see util::detlint");
                return ExitCode::SUCCESS;
            }
            other => roots.push(PathBuf::from(other)),
        }
    }

    let crate_dir = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let explicit_roots = !roots.is_empty();
    if !explicit_roots {
        // the crate's own trees: src strict, the rest relaxed; missing
        // defaults (e.g. no examples/ checkout) are skipped silently
        roots.push(crate_dir.join("src"));
        for extra in [crate_dir.join("tests"), crate_dir.join("benches"), crate_dir.join("../examples")]
        {
            if extra.is_dir() {
                roots.push(extra);
            }
        }
    }
    let manifest_file = manifest_path.unwrap_or_else(|| crate_dir.join("detlint_layers.toml"));

    let mut report = LintReport::default();
    let mut lib_files: Vec<(String, gptvq::util::detlint::SourceFile)> = Vec::new();
    for root in &roots {
        let opts = LintOptions { kind: kind_of(root), strict_precision, sanctioned: Vec::new() };
        let opts = if opts.kind == FileKind::Lib && manifest_file.is_file() {
            // precision sanctions come from the manifest; parse errors
            // there surface through the graph pass below
            let text = std::fs::read_to_string(&manifest_file).unwrap_or_default();
            let m = graph::Manifest::parse(&manifest_file.display().to_string(), &text);
            LintOptions { sanctioned: m.sanctioned_paths(), ..opts }
        } else {
            opts
        };
        match lint_tree_with(root, &opts) {
            Ok((r, files)) => {
                report.merge(r);
                if opts.kind == FileKind::Lib {
                    lib_files.extend(files);
                }
            }
            Err(e) => {
                eprintln!("detlint: cannot scan {}: {e}", root.display());
                return ExitCode::from(2);
            }
        }
    }

    // whole-crate module-graph pass over the library tree(s)
    if !lib_files.is_empty() && manifest_file.is_file() {
        match std::fs::read_to_string(&manifest_file) {
            Ok(text) => {
                let manifest =
                    graph::Manifest::parse(&manifest_file.display().to_string(), &text);
                report.violations.extend(graph::check_graph(&manifest, &lib_files));
            }
            Err(e) => {
                eprintln!("detlint: cannot read {}: {e}", manifest_file.display());
                return ExitCode::from(2);
            }
        }
    } else if !lib_files.is_empty() && !explicit_roots {
        eprintln!(
            "detlint: warning: no layering manifest at {}; graph pass skipped",
            manifest_file.display()
        );
    }

    report.sort();
    if json {
        print!("{}", report.render_json());
    } else {
        print!("{}", report.render_text());
    }
    ExitCode::from(u8::try_from(report.exit_code()).unwrap_or(1))
}
