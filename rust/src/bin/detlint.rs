//! `detlint` — determinism/robustness linter for the gptvq crate.
//!
//! Walks a source tree (default: this crate's `src/`) and flags the
//! hazard patterns that break the bitwise-determinism contract; see
//! `gptvq::util::detlint` for the rule set and waiver policy, and
//! `docs/ARCHITECTURE.md` § "Verifying the determinism contract" for how
//! this layer relates to loom/Miri/TSan.
//!
//! ```text
//! usage: detlint [--json] [ROOT...]
//! ```
//!
//! Exits 0 when every scanned file is clean (waivers included), 1 on any
//! violation, 2 on I/O errors. The final text line
//! (`detlint: N violation(s), M waiver(s), F file(s) scanned`) is stable
//! for CI grepping; `--json` emits the whole report machine-readably.

use std::path::PathBuf;
use std::process::ExitCode;

use gptvq::util::detlint::{lint_tree, LintReport};

fn main() -> ExitCode {
    let mut json = false;
    let mut roots: Vec<PathBuf> = Vec::new();
    for arg in std::env::args().skip(1) {
        match arg.as_str() {
            "--json" => json = true,
            "--help" | "-h" => {
                println!("usage: detlint [--json] [ROOT...]");
                println!("lints rust sources for determinism hazards; see util::detlint");
                return ExitCode::SUCCESS;
            }
            other => roots.push(PathBuf::from(other)),
        }
    }
    if roots.is_empty() {
        // default to this crate's src/, wherever cargo runs us from
        roots.push(PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src"));
    }

    let mut report = LintReport::default();
    for root in &roots {
        match lint_tree(root) {
            Ok(r) => {
                report.violations.extend(r.violations);
                report.waivers += r.waivers;
                report.files += r.files;
            }
            Err(e) => {
                eprintln!("detlint: cannot scan {}: {e}", root.display());
                return ExitCode::from(2);
            }
        }
    }
    if json {
        print!("{}", report.render_json());
    } else {
        print!("{}", report.render_text());
    }
    ExitCode::from(u8::try_from(report.exit_code()).unwrap_or(1))
}
