//! detlint self-test: lints the three fixture files under
//! `tests/detlint_fixtures/` and pins the exact findings.
//!
//! The fixtures are scanned *as if* they lived under `quant/` so the
//! scoped `hash-iter` rule is active; they are plain data to this test
//! (never compiled — they sit in a subdirectory of `tests/`, which
//! cargo does not treat as integration-test roots).
//!
//! This is the acceptance gate for the linter itself: a rule that stops
//! firing on its seeded violation, or a waiver that stops suppressing,
//! fails here before it silently weakens CI.

use gptvq::util::detlint::{lint_source, LintReport, Violation};

const CLEAN: &str = include_str!("detlint_fixtures/clean.rs");
const VIOLATIONS: &str = include_str!("detlint_fixtures/violations.rs");
const WAIVED: &str = include_str!("detlint_fixtures/waived.rs");

/// Sorted (line, rule) pairs for easy multiset comparison.
fn findings(vs: &[Violation]) -> Vec<(usize, &'static str)> {
    let mut out: Vec<(usize, &'static str)> = vs.iter().map(|v| (v.line, v.rule)).collect();
    out.sort_unstable();
    out
}

fn report(violations: Vec<Violation>, waivers: usize) -> LintReport {
    LintReport { violations, waivers, files: 1 }
}

#[test]
fn clean_fixture_is_clean() {
    let (vs, waived) = lint_source("quant/clean.rs", CLEAN);
    assert!(vs.is_empty(), "clean fixture flagged: {vs:?}");
    assert_eq!(waived, 0);
    assert_eq!(report(vs, waived).exit_code(), 0);
}

#[test]
fn violations_fixture_trips_every_rule_exactly_once() {
    let (vs, waived) = lint_source("quant/violations.rs", VIOLATIONS);
    assert_eq!(waived, 0, "nothing in the violations fixture is waived");
    let expected: Vec<(usize, &str)> = vec![
        (10, "partial-cmp-unwrap"), // sort_hazard comparator
        (10, "unwrap-budget"),      // 13 bare unwraps > default 10; reported at first site
        (15, "hash-iter"),          // unsorted map.iter() accumulation
        (22, "wall-clock"),         // Instant::now in compute code
        (28, "unsafe-no-safety"),   // get_unchecked without a SAFETY: comment
        (49, "bad-waiver"),         // reasonless allow(partial-cmp-unwrap)
        (50, "partial-cmp-unwrap"), // ... which therefore does NOT suppress this
    ];
    assert_eq!(findings(&vs), expected, "full findings: {vs:?}");
    assert_eq!(report(vs, waived).exit_code(), 1, "seeded violations must fail the build");
}

#[test]
fn waived_fixture_is_fully_suppressed() {
    let (vs, waived) = lint_source("quant/waived.rs", WAIVED);
    assert!(vs.is_empty(), "reasoned waivers must suppress: {vs:?}");
    // partial-cmp-unwrap + hash-iter + wall-clock consume waivers; the
    // unsafe is SAFETY-documented and the unwraps ride a budget(unwrap, 12)
    // override, neither of which consumes an allow() waiver.
    assert_eq!(waived, 3);
    assert_eq!(report(vs, waived).exit_code(), 0);
}

#[test]
fn hash_iter_stays_scoped_to_the_deterministic_core() {
    // outside quant// coordinator// serve/ the same source is legal
    let (vs, _) = lint_source("util/violations.rs", VIOLATIONS);
    assert!(
        !vs.iter().any(|v| v.rule == "hash-iter"),
        "hash-iter fired outside its scoped directories: {vs:?}"
    );
}

#[test]
fn summary_line_is_greppable() {
    let (vs, waived) = lint_source("quant/violations.rs", VIOLATIONS);
    let n = vs.len();
    let text = report(vs, waived).render_text();
    assert!(
        text.ends_with(&format!("detlint: {n} violation(s), 0 waiver(s), 1 file(s) scanned\n")),
        "summary malformed:\n{text}"
    );
}
