//! detlint self-test: lints the fixture files under
//! `tests/detlint_fixtures/` and pins the exact findings.
//!
//! The per-line fixtures are scanned *as if* they lived under `quant/`
//! so the scoped rules are active; the graph fixtures are mini source
//! trees checked against their own layering manifests. All fixtures are
//! plain data to this test (never compiled — they sit in subdirectories
//! of `tests/`, which cargo does not treat as integration-test roots,
//! and the detlint tree walk skips `detlint_fixtures` directories).
//!
//! This is the acceptance gate for the linter itself: a rule that stops
//! firing on its seeded violation, or a waiver that stops suppressing,
//! fails here before it silently weakens CI.

use gptvq::util::detlint::{
    graph, lint_source, lint_source_with, FileKind, LintOptions, LintReport, SourceFile, Violation,
};

const CLEAN: &str = include_str!("detlint_fixtures/clean.rs");
const VIOLATIONS: &str = include_str!("detlint_fixtures/violations.rs");
const WAIVED: &str = include_str!("detlint_fixtures/waived.rs");
const PRECISION: &str = include_str!("detlint_fixtures/precision.rs");
const HOT: &str = include_str!("detlint_fixtures/hot.rs");

/// Sorted (line, rule) pairs for easy multiset comparison.
fn findings(vs: &[Violation]) -> Vec<(usize, &'static str)> {
    let mut out: Vec<(usize, &'static str)> = vs.iter().map(|v| (v.line, v.rule)).collect();
    out.sort_unstable();
    out
}

fn report(violations: Vec<Violation>, waivers: usize) -> LintReport {
    LintReport { violations, waived_rules: vec!["wall-clock"; waivers], files: 1 }
}

#[test]
fn clean_fixture_is_clean() {
    let (vs, waived) = lint_source("quant/clean.rs", CLEAN);
    assert!(vs.is_empty(), "clean fixture flagged: {vs:?}");
    assert_eq!(waived, 0);
    assert_eq!(report(vs, waived).exit_code(), 0);
}

#[test]
fn violations_fixture_trips_every_rule_exactly_once() {
    let (vs, waived) = lint_source("quant/violations.rs", VIOLATIONS);
    assert_eq!(waived, 0, "nothing in the violations fixture is waived");
    let expected: Vec<(usize, &str)> = vec![
        (10, "partial-cmp-unwrap"), // sort_hazard comparator
        (10, "unwrap-budget"),      // 13 bare unwraps > default 10; reported at first site
        (15, "hash-iter"),          // unsorted map.iter() accumulation
        (22, "wall-clock"),         // Instant::now in compute code
        (28, "unsafe-no-safety"),   // get_unchecked without a SAFETY: comment
        (49, "bad-waiver"),         // reasonless allow(partial-cmp-unwrap)
        (50, "partial-cmp-unwrap"), // ... which therefore does NOT suppress this
    ];
    assert_eq!(findings(&vs), expected, "full findings: {vs:?}");
    assert_eq!(report(vs, waived).exit_code(), 1, "seeded violations must fail the build");
}

#[test]
fn waived_fixture_is_fully_suppressed() {
    let (vs, waived) = lint_source("quant/waived.rs", WAIVED);
    assert!(vs.is_empty(), "reasoned waivers must suppress: {vs:?}");
    // partial-cmp-unwrap + hash-iter + wall-clock consume waivers; the
    // unsafe is SAFETY-documented and the unwraps ride a budget(unwrap, 12)
    // override, neither of which consumes an allow() waiver.
    assert_eq!(waived, 3);
    assert_eq!(report(vs, waived).exit_code(), 0);
}

#[test]
fn hash_iter_stays_scoped_to_the_deterministic_core() {
    // outside quant// coordinator// serve/ the same source is legal
    let (vs, _) = lint_source("util/violations.rs", VIOLATIONS);
    assert!(
        !vs.iter().any(|v| v.rule == "hash-iter"),
        "hash-iter fired outside its scoped directories: {vs:?}"
    );
}

#[test]
fn precision_fixture_pins_default_and_strict_findings() {
    // default mode: as f32 / from_f64 / to_f64 / .convert( fire, the
    // widening as f64 does not, and the reasoned waiver suppresses
    let (vs, waived) = lint_source("quant/precision.rs", PRECISION);
    let expected: Vec<(usize, &str)> = vec![
        (8, "precision-cast"),  // x as f32
        (13, "precision-cast"), // E::from_f64(v)
        (14, "precision-cast"), // e.to_f64()
        (25, "precision-cast"), // m.convert()
    ];
    assert_eq!(findings(&vs), expected, "full findings: {vs:?}");
    assert_eq!(waived, 1, "the waived narrowing at the end consumes one waiver");

    // strict mode additionally flags the widening cast at line 20
    let opts = LintOptions { strict_precision: true, ..LintOptions::default() };
    let (vs, _) = lint_source_with("quant/precision.rs", PRECISION, &opts);
    let expected_strict: Vec<(usize, &str)> = vec![
        (8, "precision-cast"),
        (13, "precision-cast"),
        (14, "precision-cast"),
        (20, "precision-cast"), // x as f64, strict only
        (25, "precision-cast"),
    ];
    assert_eq!(findings(&vs), expected_strict, "strict findings: {vs:?}");

    // the same text inside a sanctioned boundary module is clean
    let (vs, _) = lint_source("tensor/ops.rs", PRECISION);
    assert!(vs.is_empty(), "sanctioned module must not fire: {vs:?}");
}

#[test]
fn hot_fixture_pins_allocation_and_marker_findings() {
    let (vs, waived) = lint_source("quant/hot.rs", HOT);
    let expected: Vec<(usize, &str)> = vec![
        (9, "hot-alloc"),  // vec![x; 4]
        (10, "hot-alloc"), // .collect()
        (11, "hot-alloc"), // .clone()
        (22, "hot-alloc"), // stray endhot marker
    ];
    assert_eq!(findings(&vs), expected, "full findings: {vs:?}");
    assert_eq!(waived, 1, "the allow(hot-alloc) scratch consumes one waiver");
    // outside the markers the same patterns are legal: Vec::with_capacity
    // on line 6 and the trailing `out` never fire
    assert!(vs.iter().all(|v| v.rule == "hot-alloc"), "{vs:?}");
}

/// Load a graph fixture mini-tree as (root-relative path, lexed file)
/// pairs, sorted, plus its parsed manifest.
fn graph_fixture(name: &str) -> (graph::Manifest, Vec<(String, SourceFile)>) {
    let root =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/detlint_fixtures/graph").join(name);
    let mut files = Vec::new();
    let mut stack = vec![root.clone()];
    while let Some(dir) = stack.pop() {
        for entry in std::fs::read_dir(&dir).expect("fixture dir") {
            let path = entry.expect("fixture entry").path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|e| e == "rs") {
                let rel = path
                    .strip_prefix(&root)
                    .expect("under root")
                    .components()
                    .map(|c| c.as_os_str().to_string_lossy())
                    .collect::<Vec<_>>()
                    .join("/");
                let text = std::fs::read_to_string(&path).expect("fixture read");
                files.push((rel, SourceFile::parse(&text)));
            }
        }
    }
    files.sort_by(|a, b| a.0.cmp(&b.0));
    let manifest_text = std::fs::read_to_string(root.join("layers.toml")).expect("manifest");
    (graph::Manifest::parse("layers.toml", &manifest_text), files)
}

/// Sorted (file, line, rule) triples.
fn graph_findings(vs: &[Violation]) -> Vec<(String, usize, &'static str)> {
    let mut out: Vec<(String, usize, &'static str)> =
        vs.iter().map(|v| (v.file.clone(), v.line, v.rule)).collect();
    out.sort();
    out
}

#[test]
fn graph_clean_fixture_has_no_findings() {
    let (manifest, files) = graph_fixture("clean");
    let vs = graph::check_graph(&manifest, &files);
    assert!(vs.is_empty(), "clean layering flagged: {vs:?}");
}

#[test]
fn graph_upward_edge_is_pinned() {
    let (manifest, files) = graph_fixture("upward");
    let vs = graph::check_graph(&manifest, &files);
    let expected = vec![("base.rs".to_string(), 5, "layer-violation")];
    assert_eq!(graph_findings(&vs), expected, "full findings: {vs:?}");
    assert!(
        vs[0].message.contains("`base` may not depend on `app`"),
        "message names the edge: {}",
        vs[0].message
    );
    let mut r = LintReport::default();
    r.violations.extend(vs);
    assert_eq!(r.exit_code(), 1, "an upward edge must fail the build");
}

#[test]
fn graph_two_module_cycle_is_pinned() {
    let (manifest, files) = graph_fixture("cycle");
    let vs = graph::check_graph(&manifest, &files);
    // both edges are declared, so no layer-violation — but the cycle is
    // flagged twice: once observed (anchored at the first x -> y site)
    // and once in the manifest's own allow-graph (anchored at its decl)
    let expected = vec![
        ("layers.toml".to_string(), 4, "module-cycle"),
        ("x.rs".to_string(), 3, "module-cycle"),
    ];
    assert_eq!(graph_findings(&vs), expected, "full findings: {vs:?}");
    assert!(vs.iter().any(|v| v.message.contains("x -> y -> x")), "{vs:?}");
}

#[test]
fn relaxed_kinds_start_clean_on_test_sources() {
    // the violations fixture's clock read and unwrap sprawl are legal in
    // test/bench trees; the correctness rules still fire
    let opts = LintOptions { kind: FileKind::Test, ..LintOptions::default() };
    let (vs, _) = lint_source_with("tests/violations.rs", VIOLATIONS, &opts);
    let rules: Vec<&str> = vs.iter().map(|v| v.rule).collect();
    assert!(!rules.contains(&"wall-clock"), "{vs:?}");
    assert!(!rules.contains(&"unwrap-budget"), "{vs:?}");
    assert!(!rules.contains(&"precision-cast"), "{vs:?}");
    assert!(rules.contains(&"partial-cmp-unwrap"), "correctness rules stay on: {vs:?}");
    assert!(rules.contains(&"unsafe-no-safety"), "correctness rules stay on: {vs:?}");
}

#[test]
fn summary_line_is_greppable() {
    let (vs, waived) = lint_source("quant/violations.rs", VIOLATIONS);
    let n = vs.len();
    let text = report(vs, waived).render_text();
    assert!(
        text.ends_with(&format!("detlint: {n} violation(s), 0 waiver(s), 1 file(s) scanned\n")),
        "summary malformed:\n{text}"
    );
    // per-rule count lines precede the summary for CI drift tracking
    assert!(text.contains("detlint: rule partial-cmp-unwrap: 2 violation(s), 0 waiver(s)"), "{text}");
}

#[test]
fn json_report_escapes_and_lists_every_rule() {
    let report = LintReport {
        violations: vec![Violation {
            file: "quant/x.rs".to_string(),
            line: 3,
            rule: "hot-alloc",
            message: "tab\there\nand newline".to_string(),
        }],
        waived_rules: vec!["precision-cast", "precision-cast", "hot-alloc"],
        files: 2,
    };
    let json = report.render_json();
    assert!(json.contains("tab\\there\\nand newline"), "{json}");
    assert!(!json.trim_end().chars().any(|c| (c as u32) < 0x20), "raw control char: {json:?}");
    assert!(json.contains("\"precision-cast\":{\"violations\":0,\"waivers\":2}"), "{json}");
    assert!(json.contains("\"hot-alloc\":{\"violations\":1,\"waivers\":1}"), "{json}");
    assert!(json.contains("\"layer-violation\":{\"violations\":0,\"waivers\":0}"), "{json}");
    assert!(json.contains("\"n_waivers\":3"), "{json}");
}
