//! Randomized overload property harness.
//!
//! The overload-control tentpole (bounded admission queue, per-request
//! step deadlines, typed shedding) has one load-bearing invariant: no
//! matter how hostile the traffic, **every offered request resolves
//! exactly once** — admitted-and-completed, shed at submit, expired at
//! its deadline, or cancelled — and the engine returns to empty (all
//! decode slots and their KV caches freed). This harness drives seeded
//! open-loop traffic ([`gptvq::serve::loadgen`]) across a grid of
//! schedulers × backends × step modes × queue caps × deadlines and
//! asserts, per trial:
//!
//! * no panic and no stall error from the shipped schedulers,
//! * exactly-once resolution for every arrival (a `BTreeMap` insert
//!   that must never displace an entry),
//! * the bounded queue never exceeds its cap at any step boundary,
//! * the engine drains to `pending() == 0`, `queued() == 0`,
//!   `active_count() == 0`,
//! * a second identically-seeded run sheds the same requests and emits
//!   bitwise-identical tokens and outcomes for every session — overload
//!   decisions live in deterministic step-time, never wall-clock.

use std::collections::BTreeMap;

use gptvq::coordinator::{quantize_model, Method, PipelineConfig};
use gptvq::data::tokens::synthetic_stream;
use gptvq::model::{Model, ModelConfig};
use gptvq::quant::gptvq::GptvqConfig;
use gptvq::serve::{
    generate, Arrival, Engine, Fifo, LoadGenConfig, Outcome, RoundRobin, Scheduler, ServeBackend,
    ShortestRemaining, StepMode, SubmitOutcome,
};
use gptvq::vqformat::VqModel;

/// How one arrival resolved, with the tokens it produced (empty unless
/// completed) — the unit of the exactly-once and rerun-identity checks.
#[derive(Debug, Clone, PartialEq, Eq)]
enum Resolution {
    Shed,
    Completed(Vec<u8>),
    Expired(usize),
    Cancelled(usize),
}

struct TrialConfig {
    max_batch: usize,
    queue_cap: usize,
    step_mode: StepMode,
    sched: fn() -> Box<dyn Scheduler>,
}

/// Quantize the trial model once; fused-backend trials clone the
/// container.
fn quantized_container(m: &Model) -> VqModel {
    let mut qm = m.clone();
    let s = synthetic_stream(4_000, 3);
    let mut g = GptvqConfig::for_setting(2, 2, 0.25);
    g.em_iters = 5;
    g.update_iters = 2;
    g.group_size = 256;
    let mut cfg = PipelineConfig::new(Method::Gptvq(g));
    cfg.calib_sequences = 2;
    cfg.calib_seq_len = 16;
    let rep = quantize_model(&mut qm, &s, &cfg).expect("quantize trial model");
    rep.vq_model.expect("pipeline emits a container")
}

/// Drive `arrivals` open-loop through a fresh engine and return the
/// per-arrival resolution map. Panics (failing the trial) on stall
/// errors, duplicate resolution, queue-cap violation, or a run that
/// exceeds the step bound (i.e. a leaked request that never resolves).
fn run_trial(
    backend: ServeBackend,
    cfg: &TrialConfig,
    arrivals: &[Arrival],
    label: &str,
) -> BTreeMap<u64, Resolution> {
    let mut e = Engine::new(backend, cfg.max_batch)
        .with_scheduler((cfg.sched)())
        .with_step_mode(cfg.step_mode)
        .with_queue_cap(cfg.queue_cap);
    let mut resolved: BTreeMap<u64, Resolution> = BTreeMap::new();
    let mut resolve = |resolved: &mut BTreeMap<u64, Resolution>, id: u64, r: Resolution| {
        assert!(
            resolved.insert(id, r).is_none(),
            "{label}: request {id} resolved more than once"
        );
    };
    let mut next = 0usize;
    let mut guard = 0u32;
    while next < arrivals.len() || e.pending() > 0 {
        guard += 1;
        assert!(guard < 50_000, "{label}: run did not drain (leaked request?)");
        let now = e.steps_elapsed();
        while next < arrivals.len() && arrivals[next].step <= now {
            let id = arrivals[next].req.id;
            match e.try_submit(arrivals[next].req.clone()).expect("non-empty prompts") {
                SubmitOutcome::Admitted(_) => {}
                SubmitOutcome::Rejected(_) => resolve(&mut resolved, id, Resolution::Shed),
            }
            next += 1;
        }
        if cfg.queue_cap > 0 {
            assert!(
                e.queued() <= cfg.queue_cap,
                "{label}: bounded queue overflowed ({} > cap {})",
                e.queued(),
                cfg.queue_cap
            );
        }
        for resp in e.step().expect("shipped schedulers never stall") {
            let r = match resp.outcome {
                Outcome::Completed => Resolution::Completed(resp.output),
                Outcome::Expired => Resolution::Expired(resp.tokens_generated),
                Outcome::Cancelled => Resolution::Cancelled(resp.tokens_generated),
            };
            resolve(&mut resolved, resp.id, r);
        }
    }
    assert_eq!(e.pending(), 0, "{label}: pending after drain");
    assert_eq!(e.queued(), 0, "{label}: queued after drain");
    assert_eq!(e.active_count(), 0, "{label}: KV slots not returned after drain");
    resolved
}

#[test]
fn overloaded_engine_resolves_every_request_exactly_once_and_deterministically() {
    const TRIALS: u64 = 24;
    let template = Model::synthetic(ModelConfig::demo(64), 911);
    let vq = quantized_container(&template);

    for t in 0..TRIALS {
        let sched: fn() -> Box<dyn Scheduler> = match t % 3 {
            0 => || Box::new(Fifo::new()),
            1 => || Box::new(RoundRobin::new()),
            _ => || Box::new(ShortestRemaining::new()),
        };
        let fused = t % 4 == 3;
        let cfg = TrialConfig {
            max_batch: 1 + (t % 3) as usize,
            // 0 = unbounded rides along so the legacy contract stays in
            // the property net
            queue_cap: [0usize, 2, 4, 7][(t / 3) as usize % 4],
            step_mode: if t % 2 == 0 { StepMode::Batched } else { StepMode::PerSlot },
            sched,
        };
        let lg = LoadGenConfig {
            seed: 0xD05 + t,
            // up to ~4x the 1-3 token/step capacity: genuinely hostile
            rate: 0.3 + 0.45 * (t % 5) as f64,
            requests: 24 + (t % 3) as usize * 8,
            prompt_max: 40,
            output_max: 10,
            burst_every: 24,
            burst_len: 8,
            // deadline 0 (= none) rides along too
            deadline_steps: [0usize, 12, 20, 40][(t / 4) as usize % 4],
            ..LoadGenConfig::default()
        };
        let arrivals = generate(&lg);
        let label = format!(
            "trial {t}: sched={} fused={fused} batch={} cap={} deadline={} rate={:.2} reqs={}",
            (cfg.sched)().name(),
            cfg.max_batch,
            cfg.queue_cap,
            lg.deadline_steps,
            lg.rate,
            arrivals.len(),
        );
        let mk_backend = || {
            if fused {
                ServeBackend::fused(&template, vq.clone())
            } else {
                ServeBackend::Dense(template.clone())
            }
        };

        let first = run_trial(mk_backend(), &cfg, &arrivals, &label);
        // exactly-once: the map covers every arrival (duplicates already
        // panic inside run_trial)
        assert_eq!(first.len(), arrivals.len(), "{label}: unresolved requests");
        for a in &arrivals {
            assert!(first.contains_key(&a.req.id), "{label}: arrival {} vanished", a.req.id);
        }

        // rerun identity: same seed, same shed set, same outcomes,
        // bitwise-same tokens for every completed session
        let second = run_trial(mk_backend(), &cfg, &arrivals, &label);
        assert_eq!(first, second, "{label}: rerun diverged");
    }
}
