// detlint hot-region fixture: seeded allocations inside a marked hot
// region, one waived scratch, and a stray end marker. Lint DATA for
// detlint_self.rs (never compiled).

pub fn hot_loop(xs: &[f64]) -> Vec<f64> {
    let mut out = Vec::with_capacity(xs.len());
    // detlint: hot(fixture-loop)
    for &x in xs {
        let v = vec![x; 4];
        let doubled: Vec<f64> = v.iter().map(|a| a * 2.0).collect();
        let copied = doubled.clone();
        // detlint: allow(hot-alloc, fixture: documented per-iteration scratch)
        let scratch = Vec::new();
        out.push(copied[0] + scratch.len() as f64);
    }
    // detlint: endhot
    out
}

// a close marker with no open region is a marker error, reported by the
// hot-alloc rule so typos cannot silently disable the check
// detlint: endhot
