//! detlint fixture: every hazard from `violations.rs` carrying a
//! *reasoned* waiver — the self-test asserts zero violations and an
//! exact waiver count, pinning the waiver-hygiene contract: a waiver
//! suppresses iff it names the rule, carries a reason, and sits on the
//! violating line or the one above. Never compiled (tests/ subdir).

use std::collections::HashMap;
use std::time::Instant;

pub fn sort_waived(v: &mut [f64]) {
    // detlint: allow(partial-cmp-unwrap, inputs are validated finite one call above)
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
}

pub fn hash_waived(map: &HashMap<usize, f64>) -> f64 {
    let mut acc = 0.0;
    // detlint: allow(hash-iter, f64 addition here is order-insensitive in test fixture land)
    for (_k, v) in map.iter() {
        acc += v;
    }
    acc
}

pub fn clock_waived() -> f64 {
    let t = Instant::now(); // detlint: allow(wall-clock, annotates a metrics line only)
    t.elapsed().as_secs_f64()
}

// SAFETY: index 0 is checked non-empty by every caller of this fixture.
pub fn unsafe_documented(v: &[u8]) -> u8 {
    unsafe { *v.get_unchecked(0) }
}

// detlint: budget(unwrap, 12) — fixture exercising the budget override
pub fn unwrap_waived(v: &[f64]) -> f64 {
    let a = v.first().unwrap();
    let b = v.get(1).unwrap();
    let c = v.get(2).unwrap();
    let d = v.get(3).unwrap();
    let e = v.get(4).unwrap();
    let f = v.get(5).unwrap();
    let g = v.get(6).unwrap();
    let h = v.get(7).unwrap();
    let i = v.get(8).unwrap();
    let j = v.get(9).unwrap();
    let k = v.get(10).unwrap();
    a + b + c + d + e + f + g + h + i + j + k
}
