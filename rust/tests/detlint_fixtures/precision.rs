// detlint precision fixture: seeded boundary crossings. This file is
// lint DATA for detlint_self.rs (never compiled — tests/ subdirectories
// are not integration-test roots) and is linted as `quant/precision.rs`,
// far from the sanctioned tensor boundary modules.

/// Narrowing cast outside the sanctioned modules: violation.
pub fn narrow(x: f64) -> f32 {
    x as f32
}

/// Boundary calls outside the sanctioned modules: one violation each.
pub fn boundary(v: f64) -> f64 {
    let e = E::from_f64(v);
    e.to_f64()
}

/// Widening cast: exact, clean by default, flagged under
/// --strict-precision only.
pub fn widen(x: f32) -> f64 {
    x as f64
}

/// Element conversion helper outside the boundary: violation.
pub fn conv(m: &Matrix) -> Matrix32 {
    m.convert()
}

/// A reasoned waiver suppresses the crossing (and is counted).
pub fn waived(x: f64) -> f32 {
    // detlint: allow(precision-cast, fixture: documented narrowing at a declared boundary)
    x as f32
}
