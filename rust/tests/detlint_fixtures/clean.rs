//! detlint fixture: a file with zero hazards — every pattern below is
//! the sanctioned counterpart of a rule's hazard. Lives under
//! `tests/detlint_fixtures/` (a subdirectory, so cargo never compiles
//! it as a test target); `tests/detlint_self.rs` feeds it through the
//! scanner and asserts zero violations and zero waivers.

use std::collections::HashMap;

pub fn ordered_walk(map: &HashMap<usize, f64>) -> Vec<(usize, f64)> {
    let mut out: Vec<(usize, f64)> = map.iter().map(|(k, v)| (*k, *v)).collect();
    out.sort_by_key(|(k, _)| *k);
    out
}

pub fn nan_safe_sort(v: &mut [f64]) {
    v.sort_by(|a, b| a.total_cmp(b));
}

pub fn careful(v: &[f64]) -> f64 {
    match v.first() {
        Some(x) => *x,
        None => 0.0,
    }
}
