// graph fixture, two-module cycle: x uses y ...

use crate::y;

pub fn x() -> u64 {
    y::y() + 1
}
