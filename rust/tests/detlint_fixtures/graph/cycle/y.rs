// graph fixture, two-module cycle: ... and y uses x right back.

use crate::x;

pub fn y() -> u64 {
    x::x() + 1
}
