// graph fixture, upward edge: the upper module itself is clean.

pub struct App;
