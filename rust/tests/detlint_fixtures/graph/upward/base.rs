// graph fixture, upward edge: the base layer reaches UP into app,
// which the manifest does not allow — a layer-violation anchored at
// the use site below.

use crate::app::App;

pub fn base(_a: App) -> u64 {
    2
}
