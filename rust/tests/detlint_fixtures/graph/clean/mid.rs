// graph fixture, clean layering: mid may use lo.

use crate::lo;

pub fn mid() -> u64 {
    lo::base() + 1
}
