// graph fixture, clean layering: hi may use mid and lo (both declared).

use crate::mid;

pub fn top() -> u64 {
    crate::lo::base() + mid::mid()
}
