// graph fixture, clean layering: the bottom module depends on nothing.

pub fn base() -> u64 {
    1
}
