//! detlint fixture: exactly one seeded violation of every rule, with
//! the expected (line, rule) pairs asserted by `tests/detlint_self.rs`.
//! Scanned as if it lived at `quant/violations.rs` so the scoped
//! `hash-iter` rule is active. Never compiled (subdirectory of tests/).

use std::collections::HashMap;
use std::time::Instant;

pub fn sort_hazard(v: &mut [f64]) {
    v.sort_by(|a, b| a.partial_cmp(b).unwrap()); // rule: partial-cmp-unwrap
}

pub fn hash_hazard(map: &HashMap<usize, f64>) -> f64 {
    let mut acc = 0.0;
    for (_k, v) in map.iter() {
        acc += v; // rule: hash-iter (accumulation order is hash order)
    }
    acc
}

pub fn clock_hazard() -> bool {
    let t = Instant::now(); // rule: wall-clock
    t.elapsed().as_nanos() % 2 == 0
}

// rule: unsafe-no-safety (no soundness-argument comment anywhere near)
pub fn unsafe_hazard(v: &[u8]) -> u8 {
    unsafe { *v.get_unchecked(0) }
}

pub fn unwrap_hazard(v: &[f64]) -> f64 {
    // rule: unwrap-budget — the default budget is 10 and, with the two
    // comparator unwraps above/below, this file carries 13 bare ones
    let a = v.first().unwrap();
    let b = v.get(1).unwrap();
    let c = v.get(2).unwrap();
    let d = v.get(3).unwrap();
    let e = v.get(4).unwrap();
    let f = v.get(5).unwrap();
    let g = v.get(6).unwrap();
    let h = v.get(7).unwrap();
    let i = v.get(8).unwrap();
    let j = v.get(9).unwrap();
    let k = v.get(10).unwrap();
    a + b + c + d + e + f + g + h + i + j + k
}

pub fn bad_waiver_hazard(v: &mut [f64]) {
    // detlint: allow(partial-cmp-unwrap)
    v.sort_by(|a, b| a.partial_cmp(b).unwrap()); // reasonless: does NOT suppress
}
