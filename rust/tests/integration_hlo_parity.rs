//! Three-layer integration: the rust-native forward pass and VQ kernels
//! must agree with the AOT HLO artifacts (L2 JAX model + L1 Pallas
//! kernels) executed through PJRT.
//!
//! These tests skip politely when `make artifacts` has not been run.

use gptvq::model::{Model, ModelConfig};
use gptvq::quant::vq::{assign_diag, Codebook};
use gptvq::report::experiments::{artifacts_available, artifacts_dir};
use gptvq::runtime::{Arg, Runtime};
use gptvq::tensor::Matrix;
use gptvq::util::Rng;

fn model_args(model: &Model) -> Vec<Arg> {
    // param order mirrors python param_names(): embed, per-layer 9, final, head
    let mut args = Vec::new();
    args.push(Arg::from_matrix(&model.embed));
    for l in &model.layers {
        args.push(Arg::from_vec_f64(&l.ln_attn));
        args.push(Arg::from_matrix(&l.wq));
        args.push(Arg::from_matrix(&l.wk));
        args.push(Arg::from_matrix(&l.wv));
        args.push(Arg::from_matrix(&l.wo));
        args.push(Arg::from_vec_f64(&l.ln_ffn));
        args.push(Arg::from_matrix(&l.w_gate));
        args.push(Arg::from_matrix(&l.w_up));
        args.push(Arg::from_matrix(&l.w_down));
    }
    args.push(Arg::from_vec_f64(&model.final_norm));
    args.push(Arg::from_matrix(&model.head));
    args
}

fn tokens(cfg: &ModelConfig, b: usize, s: usize, seed: u64) -> Vec<Vec<u8>> {
    let mut rng = Rng::new(seed);
    (0..b)
        .map(|_| (0..s).map(|_| rng.below(cfg.vocab) as u8).collect())
        .collect()
}

#[test]
fn native_logits_match_hlo_logits() {
    if !artifacts_available("tiny") {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dir = artifacts_dir();
    let model = Model::load(&dir, "tiny").unwrap();
    let Ok(mut rt) = Runtime::cpu(&dir) else {
        eprintln!("skipping: pjrt runtime unavailable");
        return;
    };

    // model_logits_tiny is lowered at B=1, S=64
    let toks = tokens(&model.cfg, 1, 64, 7);
    let mut args = vec![Arg::tokens_2d(&toks).unwrap()];
    args.extend(model_args(&model));
    let out = rt.execute("model_logits_tiny.hlo.txt", &args).unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].dims, vec![1, 64, model.cfg.vocab]);

    let native = gptvq::model::forward::forward_logits(&model, &toks[0]);
    let hlo = &out[0].data;
    let mut max_abs = 0f64;
    for t in 0..64 {
        for v in 0..model.cfg.vocab {
            let a = native.get(t, v);
            let b = hlo[t * model.cfg.vocab + v] as f64;
            max_abs = max_abs.max((a - b).abs());
        }
    }
    // rust runs f64, XLA f32: agreement to f32 resolution over the range
    assert!(max_abs < 5e-3, "logit divergence {max_abs}");
}

#[test]
fn native_nll_matches_hlo_nll() {
    if !artifacts_available("tiny") {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dir = artifacts_dir();
    let model = Model::load(&dir, "tiny").unwrap();
    let Ok(mut rt) = Runtime::cpu(&dir) else {
        eprintln!("skipping: pjrt runtime unavailable");
        return;
    };

    // model_nll_tiny is lowered at B=4, S=max_seq
    let s = model.cfg.max_seq;
    let toks = tokens(&model.cfg, 4, s, 13);
    let mut args = vec![Arg::tokens_2d(&toks).unwrap()];
    args.extend(model_args(&model));
    let out = rt.execute("model_nll_tiny.hlo.txt", &args).unwrap();
    assert_eq!(out[0].dims, vec![4, s - 1]);

    for (bi, seq) in toks.iter().enumerate() {
        let native = gptvq::model::forward::nll_per_token(&model, seq);
        for t in 0..s - 1 {
            let a = native[t];
            let b = out[0].data[bi * (s - 1) + t] as f64;
            assert!(
                (a - b).abs() < 2e-3 * (1.0 + a.abs()),
                "nll divergence at batch {bi} pos {t}: {a} vs {b}"
            );
        }
    }
}

#[test]
fn native_assign_matches_pallas_assign_kernel() {
    if !artifacts_available("tiny") {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dir = artifacts_dir();
    let Ok(mut rt) = Runtime::cpu(&dir) else {
        eprintln!("skipping: pjrt runtime unavailable");
        return;
    };
    let mut rng = Rng::new(99);

    for (d, k, file) in [
        (1usize, 8usize, "vq_assign_d1_k8_n4096.hlo.txt"),
        (2, 16, "vq_assign_d2_k16_n4096.hlo.txt"),
        (2, 64, "vq_assign_d2_k64_n4096.hlo.txt"),
        (4, 256, "vq_assign_d4_k256_n4096.hlo.txt"),
    ] {
        if !dir.join(file).exists() {
            continue;
        }
        let n = 4096;
        let pts = Matrix::from_fn(n, d, |_, _| rng.gaussian());
        let cb = Codebook::from_centroids(d, rng.gaussian_vec(k * d));
        let hd = Matrix::from_fn(n, d, |_, _| rng.range(0.1, 3.0));

        let native = assign_diag(&pts, &cb, &hd);

        let out = rt
            .execute(
                file,
                &[
                    Arg::from_matrix(&pts),
                    Arg::F32 {
                        data: cb.centroids.iter().map(|&v| v as f32).collect(),
                        dims: vec![k, d],
                    },
                    Arg::from_matrix(&hd),
                ],
            )
            .unwrap();
        assert_eq!(out[0].dims, vec![n]);

        let mut mismatches = 0usize;
        for i in 0..n {
            if out[0].data[i] as u32 != native[i] {
                mismatches += 1;
            }
        }
        // f32-vs-f64 distance ties may flip a handful of assignments
        assert!(
            mismatches <= n / 200,
            "{file}: {mismatches}/{n} assignment mismatches"
        );
    }
}

#[test]
fn serve_vq_artifact_runs_pallas_decode_head() {
    if !artifacts_available("tiny") {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dir = artifacts_dir();
    let model = Model::load(&dir, "tiny").unwrap();
    let Ok(mut rt) = Runtime::cpu(&dir) else {
        eprintln!("skipping: pjrt runtime unavailable");
        return;
    };
    let mut rng = Rng::new(5);

    // serve_vq_tiny: tokens [1, 64], head idx i32[V, D/2], codebook [16, 2]
    let (v, dm, d, k) = (model.cfg.vocab, model.cfg.d_model, 2usize, 16usize);
    let idx: Vec<i32> = (0..v * dm / d).map(|_| rng.below(k) as i32).collect();
    let cbv: Vec<f32> = (0..k * d).map(|_| rng.gaussian() as f32 * 0.05).collect();
    let toks = tokens(&model.cfg, 1, 64, 21);

    let mut args = vec![
        Arg::tokens_2d(&toks).unwrap(),
        Arg::I32 { data: idx.clone(), dims: vec![v, dm / d] },
        Arg::F32 { data: cbv.clone(), dims: vec![k, d] },
    ];
    args.extend(model_args(&model));
    // the dense `head` param is dead in this graph (replaced by the VQ
    // decode) and jax's lowering DCEs it away — drop the trailing arg
    args.pop();
    let out = rt.execute("serve_vq_tiny.hlo.txt", &args).unwrap();
    assert_eq!(out[0].dims, vec![1, 64, v]);

    // native reference: decode the head (W[i,j*d+t] = cb[idx]) and swap in
    let mut head_t = Matrix::zeros(v, dm);
    for i in 0..v {
        for j in 0..dm / d {
            let a = idx[i * (dm / d) + j] as usize;
            for t in 0..d {
                head_t.set(i, j * d + t, cbv[a * d + t] as f64);
            }
        }
    }
    let mut swapped = model.clone();
    swapped.head = head_t.transpose();
    let native = gptvq::model::forward::forward_logits(&swapped, &toks[0]);
    let mut max_abs = 0f64;
    for t in 0..64 {
        for c in 0..v {
            let a = native.get(t, c);
            let b = out[0].data[t * v + c] as f64;
            max_abs = max_abs.max((a - b).abs());
        }
    }
    assert!(max_abs < 5e-3, "serve_vq divergence {max_abs}");
}
