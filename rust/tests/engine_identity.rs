//! Randomized multi-session token-identity property harness.
//!
//! The batched engine step (one ragged cross-slot forward per step) must
//! be bitwise token-identical to the per-slot reference loop at EVERY
//! batch composition — that is the determinism contract the cross-slot
//! batching tentpole rides on. This harness drives ≥100 seeded trials of
//! mixed traffic (random admission steps, prompt/output lengths, all
//! three schedulers, dense + fused backends, speculative draft k ∈
//! {0, 2}, random step budgets, prefill chunk sizes, and paged-KV
//! configurations — page sizes {1, 3, 8, 64}, bounded and unbounded
//! arenas) and asserts the two modes agree on every per-session
//! transcript AND on the deterministic step-count timing (TTFT steps,
//! queue-wait steps). Trials with a paged arena additionally re-run
//! against a contiguous per-slot reference engine: the pool is an
//! allocator, never a math change, so dense paged transcripts must be
//! bitwise equal to the contiguous ones.

use gptvq::coordinator::{quantize_model, Method, PipelineConfig};
use gptvq::data::tokens::synthetic_stream;
use gptvq::model::{Model, ModelConfig};
use gptvq::quant::gptvq::GptvqConfig;
use gptvq::serve::{
    DecodePolicy, Engine, Fifo, GenRequest, OneToken, RoundRobin, Scheduler, SelfSpeculative,
    ServeBackend, ServeStats, Session, ShortestRemaining, StepMode,
};
use gptvq::util::Rng;
use gptvq::vqformat::VqModel;

/// One request plus the engine step it is submitted at.
struct TrialReq {
    req: GenRequest,
    submit_at: u64,
}

/// Everything a trial compares per session: tokens and deterministic
/// step-count timing. Wall-clock fields are deliberately excluded — they
/// are timing-dependent by design.
#[derive(Debug, PartialEq, Eq)]
struct Transcript {
    id: u64,
    output: Vec<u8>,
    tokens_generated: usize,
    ttft_steps: usize,
    queue_wait_steps: usize,
}

struct TrialConfig {
    max_batch: usize,
    step_budget: usize,
    prefill_chunk: usize,
    spec_k: usize,
    /// rows per KV page (0 = contiguous per-slot caches)
    kv_page: usize,
    /// arena cap in pages (0 = unbounded); sized so trials never shed —
    /// shedding would legitimately change transcripts
    kv_pages: usize,
    sched: fn() -> Box<dyn Scheduler>,
}

/// Run one trial's traffic through an engine in `mode`, submitting each
/// request at its scheduled step, then draining. Returns per-session
/// transcripts (request order) and the drain-window stats.
fn run_trial(
    backend: ServeBackend,
    cfg: &TrialConfig,
    reqs: &[TrialReq],
    mode: StepMode,
) -> (Vec<Transcript>, ServeStats) {
    let policy: Box<dyn DecodePolicy> = if cfg.spec_k > 0 {
        Box::new(SelfSpeculative::new(cfg.spec_k))
    } else {
        Box::new(OneToken::new())
    };
    let mut e = Engine::new(backend, cfg.max_batch)
        .with_scheduler((cfg.sched)())
        .with_decode(policy)
        .expect("policy attach")
        .with_step_budget(cfg.step_budget)
        .with_step_mode(mode)
        .with_prefill_chunk(cfg.prefill_chunk)
        .with_kv_page(cfg.kv_page)
        .with_kv_pages(cfg.kv_pages);
    let mut sessions: Vec<Session> = Vec::new();
    let last_submit = reqs.iter().map(|r| r.submit_at).max().unwrap_or(0);
    // manual stepping through the submission window: requests arrive at
    // randomized steps so admission hits every batch composition
    for step in 0..=last_submit {
        for r in reqs.iter().filter(|r| r.submit_at == step) {
            sessions.push(e.submit(r.req.clone()).expect("submit"));
        }
        if step < last_submit {
            e.step().expect("shipped schedulers never stall");
        }
    }
    let stats = e.run_to_completion().expect("shipped schedulers never stall");
    let transcripts = sessions
        .iter()
        .map(|s| {
            let r = s.response().expect("trial drained, all sessions finished");
            Transcript {
                id: r.id,
                output: r.output,
                tokens_generated: r.tokens_generated,
                ttft_steps: r.ttft_steps,
                queue_wait_steps: r.queue_wait_steps,
            }
        })
        .collect();
    (transcripts, stats)
}

/// Quantize the trial model into a packed container once (fused-backend
/// trials clone it).
fn quantized_container(m: &Model) -> VqModel {
    let mut qm = m.clone();
    let s = synthetic_stream(4_000, 1);
    let mut g = GptvqConfig::for_setting(2, 2, 0.25);
    g.em_iters = 5;
    g.update_iters = 2;
    g.group_size = 256;
    let mut cfg = PipelineConfig::new(Method::Gptvq(g));
    cfg.calib_sequences = 2;
    cfg.calib_seq_len = 16;
    let rep = quantize_model(&mut qm, &s, &cfg).expect("quantize trial model");
    rep.vq_model.expect("pipeline emits a container")
}

#[test]
fn batched_step_is_token_identical_to_per_slot_across_randomized_traffic() {
    const TRIALS: u64 = 108;
    let template = Model::synthetic(ModelConfig::demo(32), 907);
    let vq = quantized_container(&template);

    for t in 0..TRIALS {
        // deterministic grid over the categorical axes so every
        // scheduler × spec-k × backend cell is hit many times...
        let sched: fn() -> Box<dyn Scheduler> = match t % 3 {
            0 => || Box::new(Fifo::new()),
            1 => || Box::new(RoundRobin::new()),
            _ => || Box::new(ShortestRemaining::new()),
        };
        let spec_k = ((t / 3) % 2) * 2; // k ∈ {0, 2}
        let fused = (t / 6) % 3 == 0;
        // ...and a seeded rng over the continuous ones
        let mut rng = Rng::new(0xBA7C4 + t);
        // paged-KV axes: the ISSUE's page sizes plus "off"; a bounded
        // arena of 512 pages is generous (worst trial: 5 requests ×
        // 2 layers × 32 rows at page size 1 = 320 pages) so identity
        // trials never shed — shed traffic would change transcripts
        let kv_page = [0usize, 1, 3, 8, 64][rng.below(5)];
        let kv_pages = if kv_page == 0 { 0 } else { [0usize, 512][rng.below(2)] };
        let cfg = TrialConfig {
            max_batch: 1 + rng.below(4),
            step_budget: rng.below(3), // 0 = uncapped
            prefill_chunk: [0, 1, 2, 3, 7][rng.below(5)],
            spec_k,
            kv_page,
            kv_pages,
            sched,
        };
        let n_req = 1 + rng.below(5);
        let reqs: Vec<TrialReq> = (0..n_req)
            .map(|i| {
                // ~25% long prompts that cross the 32-token context
                // window (sliding-window + chunked-prefill interplay)
                let plen = if rng.below(4) == 0 { 20 + rng.below(25) } else { 2 + rng.below(10) };
                let prompt: Vec<u8> =
                    (0..plen).map(|_| rng.below(256) as u8).collect();
                TrialReq {
                    // 0 included: zero-budget requests retire without
                    // decoding and must do so at the same step
                    req: GenRequest::new(i as u64, prompt, rng.below(8)),
                    submit_at: rng.below(5) as u64,
                }
            })
            .collect();

        let mk_backend = || {
            if fused {
                ServeBackend::fused(&template, vq.clone())
            } else {
                ServeBackend::Dense(template.clone())
            }
        };
        let (batched, bs) = run_trial(mk_backend(), &cfg, &reqs, StepMode::Batched);
        let (per_slot, ps) = run_trial(mk_backend(), &cfg, &reqs, StepMode::PerSlot);

        let label = format!(
            "trial {t}: sched={} k={} fused={} batch={} budget={} chunk={} kv_page={} \
             kv_pages={} reqs={}",
            (cfg.sched)().name(),
            cfg.spec_k,
            fused,
            cfg.max_batch,
            cfg.step_budget,
            cfg.prefill_chunk,
            cfg.kv_page,
            cfg.kv_pages,
            n_req,
        );
        assert_eq!(batched, per_slot, "{label}: transcripts diverged");
        // dense-paged vs contiguous: the page pool is an allocator, not
        // a math change — the same traffic through contiguous per-slot
        // caches must produce bitwise-identical transcripts and timing
        if cfg.kv_page > 0 {
            let ref_cfg = TrialConfig { kv_page: 0, kv_pages: 0, ..cfg };
            let (contig, _) = run_trial(mk_backend(), &ref_cfg, &reqs, StepMode::PerSlot);
            assert_eq!(
                batched, contig,
                "{label}: paged transcripts diverged from the contiguous reference"
            );
        }
        assert_eq!(bs.decoded_tokens, ps.decoded_tokens, "{label}: decoded_tokens");
        assert_eq!(bs.engine_steps, ps.engine_steps, "{label}: engine_steps");
        assert_eq!(bs.prefill_chunks, ps.prefill_chunks, "{label}: prefill_chunks");
        assert_eq!(
            (bs.spec_drafted, bs.spec_accepted),
            (ps.spec_drafted, ps.spec_accepted),
            "{label}: speculative counters"
        );
        assert!(
            bs.decode_calls <= ps.decode_calls,
            "{label}: batched mode used MORE forwards ({} vs {})",
            bs.decode_calls,
            ps.decode_calls
        );

        // cross-check the first request against an isolated single-slot
        // one-token engine: scheduling and batching never change tokens
        let first = &reqs[0];
        if first.req.max_new_tokens > 0 && cfg.spec_k == 0 {
            let mut iso = Engine::new(mk_backend(), 1).with_step_mode(StepMode::PerSlot);
            let s = iso.submit(first.req.clone()).expect("submit");
            iso.run_to_completion().expect("isolated engine never stalls");
            let want = s.response().unwrap().output;
            let got = &batched.iter().find(|tr| tr.id == 0).unwrap().output;
            assert_eq!(got, &want, "{label}: request 0 diverged from isolated decode");
        }
    }
}
