//! Launcher smoke tests: drive the `gptvq` binary end to end via its CLI
//! (the surface a downstream user actually touches).

use std::path::PathBuf;
use std::process::Command;

fn binary() -> Option<PathBuf> {
    // target/<profile>/gptvq next to the test executable
    let mut p = std::env::current_exe().ok()?;
    p.pop(); // deps/
    p.pop(); // <profile>/
    p.push("gptvq");
    p.exists().then_some(p)
}

fn artifacts() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts().join("model_tiny.ckpt").exists()
}

#[test]
fn info_lists_models_and_manifest() {
    let (Some(bin), true) = (binary(), have_artifacts()) else {
        eprintln!("skipping: binary or artifacts missing");
        return;
    };
    let out = Command::new(&bin)
        .args(["info", "--artifacts"])
        .arg(artifacts())
        .output()
        .expect("spawn");
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("tiny"), "{stdout}");
    assert!(stdout.contains("AOT artifacts"), "{stdout}");
}

#[test]
fn quantize_eval_serve_roundtrip() {
    let (Some(bin), true) = (binary(), have_artifacts()) else {
        eprintln!("skipping: binary or artifacts missing");
        return;
    };
    let packed = std::env::temp_dir().join(format!("gvq_cli_{}.gvq", std::process::id()));

    let out = Command::new(&bin)
        .args(["quantize", "--preset", "tiny", "--method", "gptvq", "--d", "2", "--bits", "2"])
        .args(["--em-iters", "10", "--update-iters", "3", "--calib-seqs", "4", "--eval-seqs", "4"])
        .args(["--artifacts"])
        .arg(artifacts())
        .args(["--out"])
        .arg(&packed)
        .output()
        .expect("spawn quantize");
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("GPTVQ 2D 2b"), "{stdout}");
    assert!(packed.exists(), "packed model written");

    let out = Command::new(&bin)
        .args(["eval", "--preset", "tiny", "--eval-seqs", "4", "--task-items", "5", "--artifacts"])
        .arg(artifacts())
        .args(["--model"])
        .arg(&packed)
        .output()
        .expect("spawn eval");
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("perplexity:"));

    let out = Command::new(&bin)
        .args(["serve", "--preset", "tiny", "--requests", "2", "--new-tokens", "4", "--artifacts"])
        .arg(artifacts())
        .args(["--model"])
        .arg(&packed)
        .output()
        .expect("spawn serve");
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("tok/s"));

    // the Engine surface: SRPT scheduling + speculative decode on the
    // fused backend, reporting tail fairness and acceptance
    let out = Command::new(&bin)
        .args(["serve", "--preset", "tiny", "--requests", "3", "--new-tokens", "6"])
        .args(["--backend", "fused-vq", "--policy", "shortest", "--spec-draft", "2"])
        .args(["--artifacts"])
        .arg(artifacts())
        .args(["--model"])
        .arg(&packed)
        .output()
        .expect("spawn serve (speculative)");
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("shortest-remaining"), "{stdout}");
    assert!(stdout.contains("tokens/step"), "{stdout}");
    assert!(stdout.contains("speculative decode"), "{stdout}");
    assert!(stdout.contains("ttft"), "{stdout}");
    assert!(stdout.contains("step mode batched"), "{stdout}");

    // the per-slot reference mode + chunked prefill knobs
    let out = Command::new(&bin)
        .args(["serve", "--preset", "tiny", "--requests", "2", "--new-tokens", "4"])
        .args(["--step-mode", "per-slot", "--prefill-chunk", "2", "--artifacts"])
        .arg(artifacts())
        .args(["--model"])
        .arg(&packed)
        .output()
        .expect("spawn serve (per-slot, chunked)");
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("step mode per-slot"), "{stdout}");
    assert!(stdout.contains("prefill chunks"), "{stdout}");

    // open-loop traffic + overload knobs: seeded loadgen, bounded queue,
    // deadlines — the overload/slo report lines must appear
    let out = Command::new(&bin)
        .args(["serve", "--preset", "tiny", "--loadgen", "--loadgen-requests", "12"])
        .args(["--arrival-rate", "1.5", "--loadgen-seed", "5", "--queue-cap", "3"])
        .args(["--deadline-steps", "24", "--max-batch", "2", "--artifacts"])
        .arg(artifacts())
        .args(["--model"])
        .arg(&packed)
        .output()
        .expect("spawn serve (loadgen overload)");
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("loadgen: 12 requests"), "{stdout}");
    assert!(stdout.contains("overload: shed"), "{stdout}");
    assert!(stdout.contains("slo: ttft"), "{stdout}");
    assert!(stdout.contains("goodput"), "{stdout}");

    std::fs::remove_file(&packed).ok();
}

#[test]
fn unknown_subcommand_exits_nonzero() {
    let Some(bin) = binary() else {
        eprintln!("skipping: binary missing");
        return;
    };
    let out = Command::new(&bin).arg("frobnicate").output().expect("spawn");
    assert!(!out.status.success());
}

#[test]
fn bad_method_is_config_error() {
    let (Some(bin), true) = (binary(), have_artifacts()) else {
        return;
    };
    let out = Command::new(&bin)
        .args(["quantize", "--preset", "tiny", "--method", "nope", "--artifacts"])
        .arg(artifacts())
        .output()
        .expect("spawn");
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown method"));
}
