//! Exhaustive loom model checking of the `WorkerPool` protocol.
//!
//! Compiled only under `RUSTFLAGS="--cfg loom"` (CI's loom job, which
//! `cargo add --dev loom`s first — the offline build never references
//! the crate). The `util::sync` shim then swaps every Mutex / Condvar /
//! atomic / Arc inside `util::pool` for loom's model-checked twin, and
//! each `loom::model` closure below is executed once per *possible
//! interleaving* of its threads, bounded by `LOOM_MAX_PREEMPTIONS`.
//!
//! What loom exhausts here — the three protocols PR 4 shipped on faith:
//!
//! 1. **spawn/drain**: a scope's latch reaches zero exactly once, after
//!    every spawned job ran; no lost condvar wakeup between a job's
//!    final decrement and the caller's `cv.wait` (the lock/unlock
//!    pairing in the job wrapper is the load-bearing line).
//! 2. **help-while-waiting**: a caller blocked on its own batch pops and
//!    runs queued jobs (its own or a nested batch's) instead of parking,
//!    so nested fan-outs cannot deadlock even at width 2.
//! 3. **panic propagation**: a panicking job is caught, recorded in the
//!    latch's panic slot, still decrements the latch, and is re-raised
//!    on the caller after the batch drains — and the pool stays usable.
//!
//! Model sizes stay tiny (≤ 2 worker threads, ≤ 3 jobs) on purpose:
//! loom's state space is exponential in threads × synchronization ops,
//! and these sizes already cover every protocol transition. The parity
//! tests sample big schedules; loom proves the small ones completely.

#![cfg(loom)]

use std::panic::{catch_unwind, AssertUnwindSafe};

use gptvq::util::sync::atomic::{AtomicUsize, Ordering};
use gptvq::util::sync::Arc;
use gptvq::util::WorkerPool;

/// Protocol 1: every spawned job runs exactly once and the scope does
/// not return before all of them have (the latch drain), across every
/// interleaving of caller and worker.
#[test]
fn loom_scope_spawn_drain() {
    loom::model(|| {
        let pool = WorkerPool::new(2);
        let hits = Arc::new(AtomicUsize::new(0));
        pool.scope(|s| {
            for _ in 0..2 {
                let hits = Arc::clone(&hits);
                s.spawn(move || {
                    hits.fetch_add(1, Ordering::SeqCst);
                });
            }
        });
        // scope returned => latch drained => both jobs completed
        assert_eq!(hits.load(Ordering::SeqCst), 2);
        drop(pool); // Drop joins the worker; loom verifies the join
    });
}

/// Protocol 1 at the `run` level: the index-addressed fan-out calls
/// every index exactly once, caller lane included.
#[test]
fn loom_run_each_index_once() {
    loom::model(|| {
        let pool = WorkerPool::new(2);
        let hits = Arc::new([AtomicUsize::new(0), AtomicUsize::new(0)]);
        {
            let hits = Arc::clone(&hits);
            pool.run(2, move |i| {
                hits[i].fetch_add(1, Ordering::SeqCst);
            });
        }
        assert_eq!(hits[0].load(Ordering::SeqCst), 1);
        assert_eq!(hits[1].load(Ordering::SeqCst), 1);
    });
}

/// Protocol 2: a nested fan-out issued from inside a pool job makes
/// progress at width 2 — the outer waiter helps by executing queued
/// jobs instead of parking, so no interleaving deadlocks.
#[test]
fn loom_nested_scope_helps_while_waiting() {
    loom::model(|| {
        let pool = WorkerPool::new(2);
        let inner_ran = Arc::new(AtomicUsize::new(0));
        pool.scope(|s| {
            let inner_ran = Arc::clone(&inner_ran);
            let pool_ref = &pool;
            s.spawn(move || {
                // nested batch from a worker lane; the outer caller (or
                // this lane itself) must help-execute it
                pool_ref.scope(|s2| {
                    let inner_ran = Arc::clone(&inner_ran);
                    s2.spawn(move || {
                        inner_ran.fetch_add(1, Ordering::SeqCst);
                    });
                });
            });
        });
        assert_eq!(inner_ran.load(Ordering::SeqCst), 1);
    });
}

/// Protocol 3: a panicking job is re-raised on the caller only after
/// the whole batch drained — the surviving sibling job has always run —
/// and the pool remains usable for the next batch.
#[test]
fn loom_panic_propagates_after_drain() {
    loom::model(|| {
        let pool = WorkerPool::new(2);
        let sibling = Arc::new(AtomicUsize::new(0));
        let caught = {
            let sibling = Arc::clone(&sibling);
            catch_unwind(AssertUnwindSafe(|| {
                pool.scope(|s| {
                    let sibling = Arc::clone(&sibling);
                    s.spawn(move || {
                        sibling.fetch_add(1, Ordering::SeqCst);
                    });
                    s.spawn(move || panic!("modeled job panic"));
                });
            }))
        };
        assert!(caught.is_err(), "job panic must surface on the caller");
        assert_eq!(sibling.load(Ordering::SeqCst), 1, "batch drains before re-raise");
        // the pool survives: a fresh batch still completes
        let after = Arc::new(AtomicUsize::new(0));
        {
            let after = Arc::clone(&after);
            pool.run(2, move |_| {
                after.fetch_add(1, Ordering::SeqCst);
            });
        }
        assert_eq!(after.load(Ordering::SeqCst), 2);
    });
}
