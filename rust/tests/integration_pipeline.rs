//! End-to-end pipeline integration over the real build artifacts: trained
//! checkpoint -> calibration -> GPTVQ -> packed container -> eval.

use gptvq::coordinator::{quantize_model, Method, PipelineConfig};
use gptvq::eval::perplexity;
use gptvq::model::Model;
use gptvq::quant::gptvq::GptvqConfig;
use gptvq::report::experiments::{artifacts_available, artifacts_dir, ExpContext};
use gptvq::serve::{model_from_container, Engine, GenRequest, ServeBackend};
use gptvq::vqformat::VqModel;

fn fast_gptvq(d: usize, bits: u32) -> GptvqConfig {
    let mut cfg = GptvqConfig::for_setting(d, bits, 0.25);
    cfg.em_iters = 30;
    cfg.update_iters = 10;
    cfg
}

#[test]
fn gptvq_end_to_end_on_trained_tiny_model() {
    if !artifacts_available("tiny") {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let ctx = ExpContext::load("tiny").unwrap();
    let fp_ppl = ctx.fp_perplexity();

    let run = ctx.run_method(Method::Gptvq(fast_gptvq(2, 2))).unwrap();
    assert!(run.ppl.is_finite());
    // 2-bit VQ on the robust tiny model: bounded degradation
    assert!(run.ppl < fp_ppl * 1.5, "ppl exploded: {} vs fp {}", run.ppl, fp_ppl);
    // bpv near the nominal 2.25 target (geometry snapping tolerance)
    assert!((run.bpv - 2.25).abs() < 0.35, "bpv {}", run.bpv);

    // container round-trip: save, load, decode, eval parity
    let vq = run.vq_model.as_ref().expect("vq container");
    let path = std::env::temp_dir().join(format!("gvq_e2e_{}.gvq", std::process::id()));
    vq.save(&path).unwrap();
    let loaded = VqModel::load(&path).unwrap();
    let template = Model::load(artifacts_dir(), "tiny").unwrap();
    let served = model_from_container(&template, &loaded).unwrap();
    let served_ppl = perplexity(&served, &ctx.valid, ctx.eval_seqs, served.cfg.max_seq).ppl;
    assert!(
        (served_ppl - run.ppl).abs() < 1e-6 * (1.0 + run.ppl),
        "container eval {} vs direct {}",
        served_ppl,
        run.ppl
    );
    std::fs::remove_file(&path).ok();

    // generation still works on the quantized model
    let mut engine = Engine::new(ServeBackend::Dense(served), 1);
    let session = engine
        .submit(GenRequest::new(0, b"The man went to".to_vec(), 12))
        .unwrap();
    engine.run_to_completion().expect("default engine never stalls");
    let out = session.response().expect("generation finished").output;
    assert_eq!(out.len(), 12);
}

#[test]
fn method_ordering_holds_on_trained_model() {
    // Table 1 / Table 2 shape on the real trained model: GPTVQ and GPTQ
    // (error feedback) beat RTN at 2 bits
    if !artifacts_available("tiny") {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let ctx = ExpContext::load("tiny").unwrap();
    let rtn = ctx.run_method(Method::Rtn { bits: 2, group_size: 64 }).unwrap();
    let gptq = ctx.run_method(Method::Gptq { bits: 2, group_size: 64 }).unwrap();
    let vq = ctx.run_method(Method::Gptvq(fast_gptvq(2, 2))).unwrap();
    assert!(gptq.ppl <= rtn.ppl * 1.02, "gptq {} vs rtn {}", gptq.ppl, rtn.ppl);
    assert!(vq.ppl <= rtn.ppl * 1.02, "gptvq {} vs rtn {}", vq.ppl, rtn.ppl);
}

#[test]
fn sequential_and_oneshot_calibration_both_work() {
    if !artifacts_available("tiny") {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dir = artifacts_dir();
    let train = gptvq::data::tokens::read_tokens(dir.join("corpus_train.bin")).unwrap();
    for sequential in [false, true] {
        let mut model = Model::load(&dir, "tiny").unwrap();
        let mut cfg = PipelineConfig::new(Method::Gptq { bits: 3, group_size: 64 });
        cfg.calib_sequences = 4;
        cfg.calib_seq_len = 48;
        cfg.sequential = sequential;
        let rep = quantize_model(&mut model, &train, &cfg).unwrap();
        assert_eq!(rep.layers.len(), model.cfg.n_layers * 7);
        assert!(rep.layers.iter().all(|l| l.recon_loss.is_finite()));
    }
}

#[test]
fn zero_shot_probes_run_on_fp_model() {
    if !artifacts_available("tiny") {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let ctx = ExpContext::load("tiny").unwrap();
    let scores = ctx.zero_shot(&ctx.model, 10);
    assert_eq!(scores.len(), 3, "all three probe tasks present");
    for (name, acc) in scores {
        assert!((0.0..=1.0).contains(&acc), "{name}: {acc}");
    }
}

#[test]
fn quantized_weights_decode_exactly_from_packed_container() {
    if !artifacts_available("tiny") {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let ctx = ExpContext::load("tiny").unwrap();
    let run = ctx.run_method(Method::Gptvq(fast_gptvq(1, 3))).unwrap();
    let vq = run.vq_model.as_ref().unwrap();
    for (name, lin) in &vq.linears {
        let decoded = lin.decode();
        assert!(decoded.as_slice().iter().all(|v| v.is_finite()), "{name}");
        // effective container bpv is in a sane band (indices+codebooks)
        let bpv = lin.bits_per_value();
        assert!(bpv > 2.0 && bpv < 8.0, "{name}: container bpv {bpv}");
    }
}
