//! Randomized page-reuse property harness for the paged KV arena.
//!
//! Sessions churn through the pool — interleaved submits, explicit
//! cancels, and deadline expiries, across all three schedulers — and the
//! arena must stay sound through every recycling pattern:
//!
//! * **Integrity while live**: after every engine step, the pool's
//!   owner map, free list, and counters must reconcile — in particular
//!   no page may be owned by two live sessions and no page may sit on
//!   the free list while owned ([`KvPool::verify_integrity`]).
//! * **Balance at drain**: once all traffic resolves, every page is
//!   back on the free list (`allocated == 0`, `reserved == 0`,
//!   `free_list == total_pages`).
//! * **No poison in logits**: freed pages are poison-filled (NaN /
//!   garbage codes), so any stale read through a recycled page would
//!   corrupt logits and change tokens. Pooled transcripts — including
//!   the partial outputs of cancelled and expired requests — must stay
//!   bitwise identical to the same traffic through contiguous per-slot
//!   caches.
//! * **Under pressure**: a deliberately small arena sheds with
//!   [`Rejected::KvExhausted`] instead of stalling, and still balances
//!   at drain.
//!
//! [`KvPool::verify_integrity`]: gptvq::model::kvpool::KvPool::verify_integrity
//! [`Rejected::KvExhausted`]: gptvq::serve::Rejected::KvExhausted

use gptvq::model::{Model, ModelConfig};
use gptvq::serve::{
    Engine, Fifo, GenRequest, Rejected, RoundRobin, Scheduler, ServeBackend, ShortestRemaining,
    StepMode, SubmitOutcome,
};
use gptvq::util::Rng;

/// One scripted request: submitted at `submit_at`, optionally cancelled
/// at `cancel_at` (a no-op if it already resolved — deterministically so,
/// since resolution depends only on step time).
struct Op {
    submit_at: u64,
    cancel_at: Option<u64>,
    req: GenRequest,
}

/// What one request resolved to, compared bitwise across engines. Shed
/// requests record `None` (they never became sessions).
type Resolved = Option<(Vec<u8>, usize)>;

/// Replay `ops` against `engine` step by step: submit each request at
/// its step, fire scheduled cancels, audit the pool (when present) after
/// every step, and drain. Returns per-op resolutions plus the shed
/// counts `(total, kv)`.
fn drive(engine: &mut Engine, ops: &[Op]) -> (Vec<Resolved>, usize, usize) {
    let mut sessions: Vec<Option<gptvq::serve::Session>> = Vec::new();
    let (mut shed, mut shed_kv) = (0usize, 0usize);
    let mut guard = 0u32;
    loop {
        let now = engine.steps_elapsed();
        for (i, op) in ops.iter().enumerate() {
            if op.submit_at == now {
                debug_assert_eq!(sessions.len(), i);
                match engine.try_submit(op.req.clone()).expect("well-formed request") {
                    SubmitOutcome::Admitted(s) => sessions.push(Some(s)),
                    SubmitOutcome::Rejected(r) => {
                        shed += 1;
                        if matches!(r, Rejected::KvExhausted { .. }) {
                            shed_kv += 1;
                        }
                        sessions.push(None);
                    }
                }
            }
            if op.cancel_at == Some(now) {
                engine.cancel(op.req.id);
            }
        }
        let all_submitted = sessions.len() == ops.len();
        if all_submitted && engine.pending() == 0 {
            break;
        }
        engine.step().expect("shipped schedulers never stall");
        // the invariant the whole subsystem rides on: after any step —
        // mid-churn, mid-cancel, mid-expiry — the arena reconciles
        if let Some(pool) = engine.kv_pool() {
            pool.borrow().verify_integrity().expect("pool integrity violated mid-run");
        }
        guard += 1;
        assert!(guard < 20_000, "traffic failed to drain");
    }
    let resolved = sessions
        .iter()
        .map(|s| {
            s.as_ref().map(|sess| {
                let r = sess.response().expect("drained, so every session resolved");
                (r.output, r.ttft_steps)
            })
        })
        .collect();
    (resolved, shed, shed_kv)
}

fn scripted_traffic(rng: &mut Rng, n: usize) -> Vec<Op> {
    (0..n)
        .map(|i| {
            let plen = 2 + rng.below(9);
            let prompt: Vec<u8> = (0..plen).map(|_| rng.below(256) as u8).collect();
            // deadlines on ~1/3 of requests force expiry churn; explicit
            // cancels on ~1/4 force mid-decode frees
            let deadline = if rng.below(3) == 0 { 2 + rng.below(6) } else { 0 };
            let cancel_at = if rng.below(4) == 0 { Some(rng.below(12) as u64) } else { None };
            Op {
                submit_at: rng.below(8) as u64,
                cancel_at,
                req: GenRequest::new(i as u64, prompt, rng.below(7))
                    .with_deadline_steps(deadline),
            }
        })
        .collect()
}

/// Assert the drained pool has every page home: nothing allocated,
/// nothing reserved, the whole arena on the free list, and the owner
/// map consistent.
fn assert_drained_balance(engine: &Engine, label: &str) {
    let pool = engine.kv_pool().expect("paged engine has a pool");
    let p = pool.borrow();
    p.verify_integrity().unwrap_or_else(|e| panic!("{label}: {e}"));
    let st = p.stats();
    assert_eq!(st.allocated, 0, "{label}: pages still allocated at drain");
    assert_eq!(st.reserved, 0, "{label}: pages still reserved at drain");
    assert_eq!(
        st.free_list, st.total_pages,
        "{label}: free list must balance to the full arena"
    );
    assert!(st.peak_allocated > 0, "{label}: trial never touched the arena");
}

#[test]
fn churned_pages_recycle_cleanly_and_never_leak_into_logits() {
    const TRIALS: u64 = 24;
    let template = Model::synthetic(ModelConfig::demo(32), 4242);

    for t in 0..TRIALS {
        let sched: fn() -> Box<dyn Scheduler> = match t % 3 {
            0 => || Box::new(Fifo::new()),
            1 => || Box::new(RoundRobin::new()),
            _ => || Box::new(ShortestRemaining::new()),
        };
        let mode = if (t / 3) % 2 == 0 { StepMode::Batched } else { StepMode::PerSlot };
        let mut rng = Rng::new(0x9A6E5 + t);
        let kv_page = [1usize, 3, 8][rng.below(3)];
        let n_req = 6 + rng.below(5);
        let ops = scripted_traffic(&mut rng, n_req);
        // a generous arena: no shedding, so the contiguous reference
        // sees identical traffic and transcripts must match bitwise.
        // Worst case here: 10 requests × 2 layers × ceil(16/1) rows =
        // 320 pages; churn still recycles pages because cancels/expiry
        // return them mid-run and the LIFO free list hands them to the
        // next admission.
        let label = format!("trial {t}: sched={} page={kv_page} reqs={n_req}", (sched)().name());

        let mut paged = Engine::new(ServeBackend::Dense(template.clone()), 3)
            .with_scheduler(sched())
            .with_step_mode(mode)
            .with_kv_page(kv_page)
            .with_kv_pages(384);
        let (got, shed, _) = drive(&mut paged, &ops);
        assert_eq!(shed, 0, "{label}: generous arena must not shed");
        assert_drained_balance(&paged, &label);

        let mut contiguous = Engine::new(ServeBackend::Dense(template.clone()), 3)
            .with_scheduler(sched())
            .with_step_mode(mode);
        let (want, shed_c, _) = drive(&mut contiguous, &ops);
        assert_eq!(shed_c, 0);
        // bitwise transcript identity — including partial outputs of
        // cancelled/expired requests — is the poison-leak detector: a
        // stale read through a recycled page would perturb logits and
        // change at least one token somewhere in 24 churning trials
        assert_eq!(got, want, "{label}: pooled transcripts diverged from contiguous");
    }
}

#[test]
fn a_starved_arena_sheds_kv_exhausted_and_still_balances() {
    let template = Model::synthetic(ModelConfig::demo(32), 777);
    // 12 near-simultaneous requests, each needing up to 2 × 16 = 32
    // pages at page size 1, against a 64-page arena: most must shed
    // with KvExhausted, the rest complete, and the arena balances.
    let mut rng = Rng::new(0xF00D);
    let ops: Vec<Op> = (0..12)
        .map(|i| {
            let plen = 6 + rng.below(5);
            let prompt: Vec<u8> = (0..plen).map(|_| rng.below(256) as u8).collect();
            Op {
                submit_at: (i % 2) as u64,
                cancel_at: None,
                req: GenRequest::new(i as u64, prompt, 4 + rng.below(3)),
            }
        })
        .collect();
    let mut e = Engine::new(ServeBackend::Dense(template), 4)
        .with_kv_page(1)
        .with_kv_pages(64);
    let (resolved, shed, shed_kv) = drive(&mut e, &ops);
    assert!(shed_kv > 0, "a 64-page arena under 12×~32-page demand must shed");
    assert_eq!(shed, shed_kv, "nothing else sheds here: no queue cap, no deadlines");
    let completed = resolved.iter().filter(|r| r.is_some()).count();
    assert!(completed > 0, "the arena fits at least one request; some must complete");
    assert_eq!(completed + shed, 12);
    assert_drained_balance(&e, "starved arena");

    // rerun identity: the shed pattern and every transcript are pure
    // functions of (traffic, config) — bitwise stable run-to-run
    let template = Model::synthetic(ModelConfig::demo(32), 777);
    let mut e2 = Engine::new(ServeBackend::Dense(template), 4)
        .with_kv_page(1)
        .with_kv_pages(64);
    let (resolved2, shed2, shed_kv2) = drive(&mut e2, &ops);
    assert_eq!(resolved, resolved2, "rerun transcripts diverged");
    assert_eq!((shed, shed_kv), (shed2, shed_kv2), "rerun shed pattern diverged");
}
