"""Pure-jnp oracles for the Pallas kernels (L1 correctness ground truth).

Every Pallas kernel in this package has a reference implementation here;
pytest (python/tests/test_kernels.py) asserts allclose over a hypothesis
shape/dtype sweep, and the rust implementations are cross-checked against
the same semantics via the HLO artifacts.
"""

from __future__ import annotations

import jax.numpy as jnp


def ref_vq_assign(points, centroids, hdiag):
    """Hessian-weighted nearest-centroid assignment (paper eq. 4, diagonal
    sub-Hessian variant).

    points    : f32[N, d]   d-dimensional weight vectors
    centroids : f32[k, d]   codebook
    hdiag     : f32[N, d]   per-coordinate Hessian weights (>= 0)

    returns   : i32[N]      argmin_m sum_j hdiag[i,j] * (x[i,j]-c[m,j])^2
    """
    diff = points[:, None, :] - centroids[None, :, :]  # [N, k, d]
    dist = jnp.sum(hdiag[:, None, :] * diff * diff, axis=-1)  # [N, k]
    return jnp.argmin(dist, axis=-1).astype(jnp.int32)


def ref_vq_assign_dist(points, centroids, hdiag):
    """Full distance matrix [N, k] (used to test tie behaviour)."""
    diff = points[:, None, :] - centroids[None, :, :]
    return jnp.sum(hdiag[:, None, :] * diff * diff, axis=-1)


def ref_vq_decode(indices, codebook):
    """Decode VQ indices to a dense weight matrix.

    indices  : i32[r, c//d] indices into the codebook, per d-column strip
    codebook : f32[k, d]

    returns  : f32[r, c] with W[i, j*d+t] = codebook[indices[i, j], t]
    """
    r, cg = indices.shape
    k, d = codebook.shape
    return codebook[indices].reshape(r, cg * d)


def ref_vq_decode_matmul(x, indices, codebook):
    """y = x @ decode(indices, codebook).T

    x        : f32[B, c]
    indices  : i32[r, c//d]
    codebook : f32[k, d]
    returns  : f32[B, r]
    """
    w = ref_vq_decode(indices, codebook)
    return x @ w.T


def ref_rmsnorm(x, weight, eps=1e-5):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * (1.0 / jnp.sqrt(ms + eps)) * weight
