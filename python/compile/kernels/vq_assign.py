"""Pallas kernel: Hessian-weighted nearest-centroid assignment (eq. 4).

This is the inner-loop hot spot of GPTVQ's EM initialization and of the
per-strip quantization step in Algorithm 1: for every d-dimensional weight
vector, find the codebook entry minimizing the Hessian-weighted squared
distance.

TPU mapping (DESIGN.md §Hardware-Adaptation): the paper's CUDA baselines
tile the [N, k] distance matrix over threadblocks; here BlockSpec tiles N
into VMEM-resident strips while the whole codebook (k*d <= 64k floats for
every paper setting) stays resident, so each grid step streams one point
tile HBM->VMEM and the distance reduction is a fused VPU broadcast-multiply
rather than a WMMA call. interpret=True everywhere — the CPU PJRT plugin
cannot execute Mosaic custom-calls; the lowered HLO is what rust runs.

VMEM budget per grid step (f32): TILE_N*(d [points] + d [hdiag] + k [dist])
+ k*d [codebook]. With TILE_N=512, d=4, k=4096 that is ~10.6 MB — under the
16 MB VMEM target documented in DESIGN.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_N = 512


def _assign_kernel(points_ref, centroids_ref, hdiag_ref, out_ref):
    """One grid step: assign TILE_N points against the resident codebook."""
    pts = points_ref[...]  # [tn, d]
    hd = hdiag_ref[...]  # [tn, d]
    cb = centroids_ref[...]  # [k, d]
    # [tn, k, d] broadcast difference; d is tiny (1/2/4) so the dominant
    # axis layout is the [tn, k] distance plane, which the VPU vectorizes.
    diff = pts[:, None, :] - cb[None, :, :]
    dist = jnp.sum(hd[:, None, :] * diff * diff, axis=-1)
    out_ref[...] = jnp.argmin(dist, axis=-1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("tile_n",))
def vq_assign(points, centroids, hdiag, tile_n: int = DEFAULT_TILE_N):
    """Pallas-tiled Hessian-weighted assignment.

    points    : f32[N, d]
    centroids : f32[k, d]
    hdiag     : f32[N, d]
    returns   : i32[N]
    """
    n, d = points.shape
    k, dc = centroids.shape
    assert d == dc, f"dim mismatch {d} vs {dc}"
    tn = min(tile_n, n)
    assert n % tn == 0, f"N={n} must be divisible by tile {tn}"
    grid = (n // tn,)
    return pl.pallas_call(
        _assign_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tn, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),  # codebook resident
            pl.BlockSpec((tn, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=True,
    )(points, centroids, hdiag)


def vmem_bytes(tile_n: int, d: int, k: int) -> int:
    """Static VMEM footprint model for one grid step (f32 = 4 bytes)."""
    return 4 * (tile_n * d * 2 + k * d + tile_n * k)
