"""L1 Pallas kernels (build-time only; lowered into the L2 HLO)."""

from .ref import (  # noqa: F401
    ref_rmsnorm,
    ref_vq_assign,
    ref_vq_assign_dist,
    ref_vq_decode,
    ref_vq_decode_matmul,
)
from .vq_assign import vq_assign  # noqa: F401
from .vq_decode_matmul import vq_decode_matmul  # noqa: F401
