"""Pallas kernel: fused VQ decode + matmul (the inference hot path).

The paper's §4.2 argument is that VQ-compressed weights can be *decoded
faster than int4 can be dequantized* because fewer bytes move; on Arm they
decode with TBL (in-register LUT). The TPU analog implemented here keeps
the codebook resident in VMEM as the LUT, streams the (small) index matrix
HBM->VMEM, decodes a weight tile by gather, and immediately feeds it to the
MXU-shaped dot — the decoded tile never round-trips to HBM.

y = x @ decode(idx, codebook).T     (weights stored row=output-channel)

VMEM per grid step (f32): TILE_R*cg [idx as i32] + k*d [LUT] + TILE_R*c
[decoded tile] + B*c [x tile] + B*TILE_R [out]. For B=8, c=1024, TILE_R=256,
k=256, d=4: ~2.3 MB.

interpret=True: CPU PJRT cannot run Mosaic custom-calls; the lowered HLO is
what the rust runtime executes and what the python tests check against
ref.ref_vq_decode_matmul.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_R = 256


def _decode_matmul_kernel(x_ref, idx_ref, cb_ref, out_ref):
    x = x_ref[...]  # [B, c]
    idx = idx_ref[...]  # [tr, cg]
    cb = cb_ref[...]  # [k, d]
    tr, cg = idx.shape
    k, d = cb.shape
    # LUT decode: gather codebook rows, flatten the d-axis back into columns.
    w = cb[idx].reshape(tr, cg * d)  # [tr, c]
    out_ref[...] = jnp.dot(x, w.T, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("tile_r",))
def vq_decode_matmul(x, indices, codebook, tile_r: int = DEFAULT_TILE_R):
    """Fused decode+matmul.

    x        : f32[B, c]
    indices  : i32[r, c//d]
    codebook : f32[k, d]
    returns  : f32[B, r]
    """
    b, c = x.shape
    r, cg = indices.shape
    k, d = codebook.shape
    assert cg * d == c, f"index/cols mismatch: {cg}*{d} != {c}"
    tr = min(tile_r, r)
    assert r % tr == 0, f"r={r} must divide by tile {tr}"
    grid = (r // tr,)
    return pl.pallas_call(
        _decode_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, c), lambda i: (0, 0)),  # activations resident
            pl.BlockSpec((tr, cg), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),  # LUT resident
        ],
        out_specs=pl.BlockSpec((b, tr), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((b, r), jnp.float32),
        interpret=True,
    )(x, indices, codebook)


def vmem_bytes(b: int, c: int, tile_r: int, k: int, d: int) -> int:
    """Static VMEM footprint model for one grid step."""
    cg = c // d
    return 4 * (tile_r * cg + k * d + tile_r * c + b * c + b * tile_r)
