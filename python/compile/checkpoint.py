"""GVQCKPT1 checkpoint container — the JAX→rust weight interchange format.

Layout (little-endian throughout):

    magic   : 8 bytes  b"GVQCKPT1"
    count   : u32      number of tensors
    repeat count times:
      name_len : u16
      name     : utf-8 bytes
      dtype    : u8    0=f32 1=i32 2=u8 3=u16
      ndim     : u8
      dims     : ndim x u32
      data     : raw little-endian values

The rust reader lives in rust/src/model/checkpoint.rs and must stay in
sync with this file (tested by the round-trip integration test).
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = b"GVQCKPT1"

_DTYPES = {0: np.float32, 1: np.int32, 2: np.uint8, 3: np.uint16}
_DTYPE_CODES = {np.dtype(v): k for k, v in _DTYPES.items()}


def save(path: str, tensors: dict[str, np.ndarray]) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors.items():
            # note: np.ascontiguousarray would promote 0-d to 1-d
            arr = np.asarray(arr, order="C")
            code = _DTYPE_CODES[arr.dtype]
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", code, arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes())


def load(path: str) -> dict[str, np.ndarray]:
    tensors: dict[str, np.ndarray] = {}
    with open(path, "rb") as f:
        assert f.read(8) == MAGIC, "bad checkpoint magic"
        (count,) = struct.unpack("<I", f.read(4))
        for _ in range(count):
            (name_len,) = struct.unpack("<H", f.read(2))
            name = f.read(name_len).decode("utf-8")
            code, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
            dtype = np.dtype(_DTYPES[code])
            n = int(np.prod(dims)) if ndim else 1
            data = np.frombuffer(f.read(n * dtype.itemsize), dtype=dtype)
            tensors[name] = data.reshape(dims)
    return tensors
