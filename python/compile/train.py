"""Build-time pretraining of the evaluation substrate model.

Trains the Llama-architecture byte LM (model.py) on the synthetic corpus
(corpus.py) with Adam, and writes:

    artifacts/model_<preset>.ckpt   GVQCKPT1 weights (rust-readable)
    artifacts/model_<preset>.meta   key=value config + training record
    artifacts/corpus_train.bin      GVQTOKS1 token stream
    artifacts/corpus_valid.bin

Python never runs at request time: this is the `make artifacts` path only.

Usage: python -m compile.train --preset small --out ../artifacts
"""

from __future__ import annotations

import argparse
import math
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import checkpoint, corpus
from .model import PRESETS, ModelConfig, init_params, loss_fn, param_names

TRAIN_CHARS = 2_000_000
VALID_CHARS = 200_000
CORPUS_SEED = 1234

STEPS = {"tiny": 120, "small": 350, "base": 450}
BATCH = 8
LR = 1e-3
WARMUP = 20


def sample_batch(rng: np.random.Generator, tokens: np.ndarray, batch: int, seq: int):
    starts = rng.integers(0, len(tokens) - seq - 1, size=batch)
    return np.stack([tokens[s : s + seq].astype(np.int32) for s in starts])


def adam_update(params, grads, m, v, step, lr):
    b1, b2, eps = 0.9, 0.999, 1e-8
    new_params, new_m, new_v = {}, {}, {}
    for key in params:
        g = grads[key]
        m_k = b1 * m[key] + (1 - b1) * g
        v_k = b2 * v[key] + (1 - b2) * g * g
        mh = m_k / (1 - b1**step)
        vh = v_k / (1 - b2**step)
        new_params[key] = params[key] - lr * mh / (jnp.sqrt(vh) + eps)
        new_m[key], new_v[key] = m_k, v_k
    return new_params, new_m, new_v


def lr_schedule(step: int, total: int) -> float:
    if step <= WARMUP:
        return LR * step / WARMUP
    frac = (step - WARMUP) / max(1, total - WARMUP)
    return LR * 0.5 * (1 + math.cos(math.pi * frac))


def evaluate(cfg: ModelConfig, params, tokens: np.ndarray, n_batches: int = 8):
    rng = np.random.default_rng(0)
    loss_jit = jax.jit(lambda p, t: loss_fn(cfg, p, t))
    losses = []
    for _ in range(n_batches):
        batch = sample_batch(rng, tokens, BATCH, cfg.max_seq)
        losses.append(float(loss_jit(params, jnp.asarray(batch))))
    return float(np.mean(losses))


def train(preset: str, out_dir: str, seed: int = 0) -> dict:
    cfg = PRESETS[preset]
    steps = STEPS[preset]
    os.makedirs(out_dir, exist_ok=True)

    train_path = os.path.join(out_dir, "corpus_train.bin")
    valid_path = os.path.join(out_dir, "corpus_valid.bin")
    if os.path.exists(train_path) and os.path.exists(valid_path):
        train_toks = corpus.read_tokens(train_path)
        valid_toks = corpus.read_tokens(valid_path)
    else:
        train_toks, valid_toks = corpus.build_splits(CORPUS_SEED, TRAIN_CHARS, VALID_CHARS)
        corpus.write_tokens(train_path, train_toks)
        corpus.write_tokens(valid_path, valid_toks)

    params = init_params(cfg, seed=seed)
    m = {key: jnp.zeros_like(val) for key, val in params.items()}
    v = {key: jnp.zeros_like(val) for key, val in params.items()}

    @jax.jit
    def step_fn(params, m, v, batch, step, lr):
        loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, batch))(params)
        params, m, v = adam_update(params, grads, m, v, step, lr)
        return params, m, v, loss

    rng = np.random.default_rng(seed + 99)
    t0 = time.time()
    first_loss = last_loss = None
    for step in range(1, steps + 1):
        batch = jnp.asarray(sample_batch(rng, train_toks, BATCH, cfg.max_seq))
        lr = lr_schedule(step, steps)
        params, m, v, loss = step_fn(params, m, v, batch, jnp.float32(step), jnp.float32(lr))
        if step == 1:
            first_loss = float(loss)
        last_loss = float(loss)
        if step % 50 == 0 or step == 1:
            print(f"[train/{preset}] step {step}/{steps} loss {float(loss):.4f} "
                  f"lr {lr:.2e} elapsed {time.time()-t0:.0f}s", flush=True)

    valid_loss = evaluate(cfg, params, valid_toks)
    ppl = math.exp(valid_loss)
    print(f"[train/{preset}] done: train loss {first_loss:.3f} -> {last_loss:.3f}, "
          f"valid ppl {ppl:.3f}", flush=True)

    np_params = {key: np.asarray(val) for key, val in params.items()}
    ckpt_path = os.path.join(out_dir, f"model_{preset}.ckpt")
    checkpoint.save(ckpt_path, np_params)

    meta = dict(cfg.meta_dict())
    meta.update(
        preset=preset,
        steps=steps,
        train_loss_first=round(first_loss, 4),
        train_loss_last=round(last_loss, 4),
        valid_loss=round(valid_loss, 4),
        valid_ppl=round(ppl, 4),
        params=cfg.param_count(),
    )
    with open(os.path.join(out_dir, f"model_{preset}.meta"), "w") as f:
        for key, val in meta.items():
            f.write(f"{key}={val}\n")

    # sanity: checkpoint round-trips and covers the full schema
    loaded = checkpoint.load(ckpt_path)
    assert set(loaded) == set(param_names(cfg))
    return meta


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="small", choices=sorted(PRESETS))
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    train(args.preset, args.out, seed=args.seed)


if __name__ == "__main__":
    main()
