"""Zero-shot probe task generation (LM-eval-harness substitute).

The paper reports zero-shot accuracy on PIQA/ARC/BoolQ/HellaSwag/WinoGrande,
all of which are scored by ranking the LM likelihood of candidate
completions. We reproduce that *metric form* with three synthetic probes
over the same corpus distribution (DESIGN.md §5):

  cloze      — complete a sentence with the right word class vs distractors
  pair       — pick the genuine next sentence over a word-shuffled one
  induction  — repeat-a-pattern completion (w1 w2 w3 w4 . w1 w2 w3 -> w4)

Binary format GVQTASK1 (little-endian), read by rust/src/eval/tasks.rs:

    magic      : 8 bytes  b"GVQTASK1"
    n_items    : u32
    n_choices  : u8
    per item:
      correct    : u8
      prompt_len : u16, prompt bytes (byte-level tokens)
      per choice: len u16, bytes
"""

from __future__ import annotations

import struct

import numpy as np

from .corpus import ADJS, ADVS, NOUNS, VERBS, generate_text

N_CHOICES = 4


def _sentences(seed: int, n_chars: int) -> list[str]:
    text = generate_text(seed, n_chars)
    sents = [s.strip() for s in text.replace("\n", " ").split(".")]
    return [s + "." for s in sents if len(s.split()) >= 5]


def make_cloze(seed: int, n_items: int) -> list[tuple[str, list[str], int]]:
    rng = np.random.default_rng(seed)
    sents = _sentences(seed + 1, 400_000)
    items = []
    pools = [NOUNS, VERBS, ADJS, ADVS]
    for s in sents:
        if len(items) >= n_items:
            break
        words = s.split()
        target = words[-1].rstrip(".")
        prompt = " ".join(words[:-1]) + " "
        distractor_pool = pools[int(rng.integers(0, len(pools)))]
        distractors = []
        while len(distractors) < N_CHOICES - 1:
            w = distractor_pool[int(rng.integers(0, len(distractor_pool)))]
            if w != target and w not in distractors:
                distractors.append(w)
        correct = int(rng.integers(0, N_CHOICES))
        choices = distractors[:correct] + [target + "."] + distractors[correct:]
        choices = [c if c.endswith(".") else c + "." for c in choices]
        items.append((prompt, choices, correct))
    return items


def make_pair(seed: int, n_items: int) -> list[tuple[str, list[str], int]]:
    rng = np.random.default_rng(seed)
    sents = _sentences(seed + 2, 600_000)
    items = []
    for i in range(0, len(sents) - 1, 2):
        if len(items) >= n_items:
            break
        prompt = sents[i] + " "
        genuine = sents[i + 1]
        choices = [genuine]
        while len(choices) < N_CHOICES:
            w = genuine.rstrip(".").split()
            rng.shuffle(w)
            shuffled = " ".join(w) + "."
            if shuffled not in choices:
                choices.append(shuffled)
        correct = int(rng.integers(0, N_CHOICES))
        choices[0], choices[correct] = choices[correct], choices[0]
        items.append((prompt, choices, correct))
    return items


def make_induction(seed: int, n_items: int) -> list[tuple[str, list[str], int]]:
    rng = np.random.default_rng(seed)
    items = []
    for _ in range(n_items):
        words = [NOUNS[int(rng.integers(0, len(NOUNS)))] for _ in range(4)]
        prompt = " ".join(words) + " . " + " ".join(words[:3]) + " "
        target = words[3]
        distractors = []
        while len(distractors) < N_CHOICES - 1:
            w = NOUNS[int(rng.integers(0, len(NOUNS)))]
            if w != target and w not in distractors and w not in words:
                distractors.append(w)
        correct = int(rng.integers(0, N_CHOICES))
        choices = distractors[:correct] + [target] + distractors[correct:]
        items.append((prompt, choices, correct))
    return items


def write_task(path: str, items: list[tuple[str, list[str], int]]) -> None:
    with open(path, "wb") as f:
        f.write(b"GVQTASK1")
        f.write(struct.pack("<IB", len(items), N_CHOICES))
        for prompt, choices, correct in items:
            assert len(choices) == N_CHOICES
            pb = prompt.encode("utf-8")
            f.write(struct.pack("<B", correct))
            f.write(struct.pack("<H", len(pb)))
            f.write(pb)
            for ch in choices:
                cb = ch.encode("utf-8")
                f.write(struct.pack("<H", len(cb)))
                f.write(cb)


def read_task(path: str):
    items = []
    with open(path, "rb") as f:
        assert f.read(8) == b"GVQTASK1"
        n_items, n_choices = struct.unpack("<IB", f.read(5))
        for _ in range(n_items):
            (correct,) = struct.unpack("<B", f.read(1))
            (plen,) = struct.unpack("<H", f.read(2))
            prompt = f.read(plen).decode("utf-8")
            choices = []
            for _ in range(n_choices):
                (clen,) = struct.unpack("<H", f.read(2))
                choices.append(f.read(clen).decode("utf-8"))
            items.append((prompt, choices, correct))
    return items


TASKS = {"cloze": make_cloze, "pair": make_pair, "induction": make_induction}


def write_all(out_dir: str, n_items: int = 200, seed: int = 5150) -> None:
    import os

    for idx, (name, fn) in enumerate(sorted(TASKS.items())):
        items = fn(seed + 101 * idx, n_items)
        write_task(os.path.join(out_dir, f"task_{name}.bin"), items)
        print(f"[tasks] wrote {len(items)} items for {name}")
