"""Synthetic English-like corpus generator (WikiText2 substitute).

The paper calibrates on 128x2048-token WikiText2 samples and evaluates
perplexity on the WikiText2 validation set. Neither the dataset nor network
access is available here, so we generate a deterministic, English-like
corpus with:

  * a Zipf-distributed vocabulary of real English words,
  * a small class-based grammar (determiner noun verb ... ) so byte-level
    models reach a non-trivial but clearly sub-random perplexity,
  * topic states that persist across sentences (long-ish range statistics),
  * a disjoint train/validation split by topic seed.

Everything is keyed off an explicit PCG64 seed: `make artifacts` is
reproducible bit-for-bit.
"""

from __future__ import annotations

import numpy as np

# Compact word inventory grouped by syntactic class. Enough diversity that
# the byte LM has real work to do; small enough to keep the generator tiny.
NOUNS = (
    "time year people way day man thing woman life child world school "
    "state family student group country problem hand part place case week "
    "company system program question work government number night point "
    "home water room mother area money story fact month lot right study "
    "book eye job word business issue side kind head house service friend "
    "father power hour game line end member law car city community name "
    "team minute idea body information back parent face others level office "
    "door health person art war history party result change morning reason "
    "research girl guy moment air teacher force education".split()
)
VERBS = (
    "said made went took came wanted used found gave told worked called "
    "tried asked needed felt became left put meant kept began seemed helped "
    "talked turned started showed heard played ran moved liked lived "
    "believed held brought happened wrote provided sat stood lost paid met "
    "included continued set learned changed led understood watched followed "
    "stopped created spoke read allowed added spent grew opened walked won "
    "offered remembered loved considered appeared bought waited served "
    "died sent expected built stayed fell reached killed remained".split()
)
ADJS = (
    "good new first last long great little own other old right big high "
    "different small large next early young important few public bad same "
    "able free sure low late hard major better economic strong possible "
    "whole real certain political national only common poor natural "
    "significant similar hot dead central happy serious ready simple left "
    "physical general environmental financial blue democratic dark various "
    "entire close legal religious cold final main green nice huge popular "
    "traditional cultural".split()
)
DETS = "the a this that each every some any the the the a a".split()
PREPS = "of in to for with on at from by about as into like through after over".split()
CONJS = "and but or so because while although when if since".split()
ADVS = (
    "quickly slowly carefully quietly suddenly finally usually really very "
    "often always never sometimes almost together again alone early today "
    "now then here there still just well also even back only".split()
)

SENTENCE_TEMPLATES = (
    ("D", "A", "N", "V", "P", "D", "N", "."),
    ("D", "N", "V", "D", "A", "N", "."),
    ("P", "D", "N", ",", "D", "N", "V", "R", "."),
    ("D", "N", "P", "D", "N", "V", "D", "A", "N", "."),
    ("R", ",", "D", "A", "N", "V", "."),
    ("D", "N", "V", "C", "D", "N", "V", "D", "N", "."),
    ("D", "A", "A", "N", "V", "P", "D", "N", "P", "D", "N", "."),
    ("N", "V", "D", "N", ",", "C", "N", "V", "D", "N", "."),
)

CLASS_WORDS = {
    "N": NOUNS,
    "V": VERBS,
    "A": ADJS,
    "D": DETS,
    "P": PREPS,
    "C": CONJS,
    "R": ADVS,
}


def _zipf_pick(rng: np.random.Generator, words, topic_offset: int) -> str:
    """Zipf-ish pick with a per-topic rotation so topics have distinct
    high-frequency vocabulary (gives the corpus long-range structure)."""
    n = len(words)
    # zipf over ranks, clipped
    r = int(rng.zipf(1.3))
    r = min(r, n) - 1
    return words[(r + topic_offset) % n]


def generate_text(seed: int, n_chars: int) -> str:
    """Generate ~n_chars of deterministic English-like text."""
    rng = np.random.default_rng(np.random.PCG64(seed))
    out: list[str] = []
    total = 0
    topic = int(rng.integers(0, 1 << 30))
    sentences_left_in_topic = int(rng.integers(8, 24))
    while total < n_chars:
        if sentences_left_in_topic <= 0:
            topic = int(rng.integers(0, 1 << 30))
            sentences_left_in_topic = int(rng.integers(8, 24))
            out.append("\n")
            total += 1
        template = SENTENCE_TEMPLATES[int(rng.integers(0, len(SENTENCE_TEMPLATES)))]
        words: list[str] = []
        for cls in template:
            if cls in (".", ","):
                # attach punctuation to the previous word
                if words:
                    words[-1] = words[-1] + cls
                else:
                    words.append(cls)
                continue
            inventory = CLASS_WORDS[cls]
            words.append(_zipf_pick(rng, inventory, topic % len(inventory)))
        sentence = " ".join(words)
        sentence = sentence[0].upper() + sentence[1:] + " "
        out.append(sentence)
        total += len(sentence)
        sentences_left_in_topic -= 1
    return "".join(out)[:n_chars]


def tokenize(text: str) -> np.ndarray:
    """Byte-level tokenization; vocab is exactly 256."""
    return np.frombuffer(text.encode("utf-8", errors="replace"), dtype=np.uint8)


def build_splits(seed: int, n_train: int, n_valid: int):
    """Disjoint train/valid by construction: different generator streams."""
    train = tokenize(generate_text(seed, n_train))
    valid = tokenize(generate_text(seed + 7919, n_valid))
    return train, valid


def write_tokens(path: str, tokens: np.ndarray) -> None:
    with open(path, "wb") as f:
        f.write(b"GVQTOKS1")
        f.write(np.uint64(len(tokens)).tobytes())
        f.write(tokens.astype(np.uint8).tobytes())


def read_tokens(path: str) -> np.ndarray:
    with open(path, "rb") as f:
        magic = f.read(8)
        assert magic == b"GVQTOKS1", f"bad magic {magic!r}"
        (n,) = np.frombuffer(f.read(8), dtype=np.uint64)
        data = np.frombuffer(f.read(int(n)), dtype=np.uint8)
    assert len(data) == int(n)
    return data
