"""L2: Llama-architecture byte-level LM in JAX (build-time only).

This is the evaluation substrate standing in for Llama-v2/Mistral (see
DESIGN.md §5): same layer family — RMSNorm, rotary attention, SwiGLU FFN —
at a size trainable on this machine. The forward pass is what aot.py lowers
to HLO text for the rust runtime, and train.py optimizes it against the
synthetic corpus.

Conventions (the rust native forward in rust/src/model/ mirrors these
EXACTLY; the integration test cross-checks logits):

  * activations are row-major [T, D]; weights are [D_in, D_out]; y = x @ W
  * RoPE uses the split-half convention (rotate pairs (i, i+hd/2)),
    theta = 10000, applied to q and k per head
  * RMSNorm eps = 1e-5
  * attention is causal, scaled by 1/sqrt(head_dim)
  * FFN is SwiGLU: (silu(x@Wg) * (x@Wu)) @ Wd
  * the unembedding (head) is untied from the embedding

`use_pallas=True` routes the quantized-linear path through the L1
vq_decode_matmul kernel so the kernels lower into the same HLO module.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    vocab: int = 256
    d_model: int = 160
    n_layers: int = 4
    n_heads: int = 4
    d_ffn: int = 432
    max_seq: int = 128
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        d, f, v, L = self.d_model, self.d_ffn, self.vocab, self.n_layers
        per_layer = 4 * d * d + 3 * d * f + 2 * d
        return v * d + L * per_layer + d + d * v

    def meta_dict(self) -> dict:
        return {
            "vocab": self.vocab,
            "d_model": self.d_model,
            "n_layers": self.n_layers,
            "n_heads": self.n_heads,
            "d_ffn": self.d_ffn,
            "max_seq": self.max_seq,
            "rope_theta": self.rope_theta,
            "norm_eps": self.norm_eps,
        }


PRESETS = {
    # fast CI artifacts — a couple of minutes end to end
    "tiny": ModelConfig(d_model=64, n_layers=2, n_heads=2, d_ffn=176, max_seq=64),
    # the main experiment model (~1.3M params)
    "small": ModelConfig(d_model=160, n_layers=4, n_heads=4, d_ffn=432, max_seq=128),
    # the "larger model" column of the main table (~3.3M params)
    "base": ModelConfig(d_model=256, n_layers=4, n_heads=4, d_ffn=688, max_seq=128),
}

# Weight-name schema shared with rust (rust/src/model/mod.rs).
def param_names(cfg: ModelConfig) -> list[str]:
    names = ["embed"]
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        names += [
            p + "ln_attn",
            p + "attn.wq",
            p + "attn.wk",
            p + "attn.wv",
            p + "attn.wo",
            p + "ln_ffn",
            p + "ffn.w_gate",
            p + "ffn.w_up",
            p + "ffn.w_down",
        ]
    names += ["final_norm", "head"]
    return names


def init_params(cfg: ModelConfig, seed: int = 0) -> dict[str, jnp.ndarray]:
    """Scaled-normal init (GPT-2 style residual scaling)."""
    rng = np.random.default_rng(np.random.PCG64(seed))
    d, f, v = cfg.d_model, cfg.d_ffn, cfg.vocab
    resid_scale = 1.0 / math.sqrt(2 * cfg.n_layers)

    def normal(shape, std):
        return jnp.asarray(rng.normal(0.0, std, size=shape), dtype=jnp.float32)

    params: dict[str, jnp.ndarray] = {"embed": normal((v, d), 0.02)}
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        params[p + "ln_attn"] = jnp.ones((d,), jnp.float32)
        params[p + "attn.wq"] = normal((d, d), 0.02)
        params[p + "attn.wk"] = normal((d, d), 0.02)
        params[p + "attn.wv"] = normal((d, d), 0.02)
        params[p + "attn.wo"] = normal((d, d), 0.02 * resid_scale)
        params[p + "ln_ffn"] = jnp.ones((d,), jnp.float32)
        params[p + "ffn.w_gate"] = normal((d, f), 0.02)
        params[p + "ffn.w_up"] = normal((d, f), 0.02)
        params[p + "ffn.w_down"] = normal((f, d), 0.02 * resid_scale)
    params["final_norm"] = jnp.ones((d,), jnp.float32)
    params["head"] = normal((d, v), 0.02)
    return params


def rmsnorm(x, w, eps):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * w


def rope_angles(cfg: ModelConfig, seq: int):
    hd = cfg.head_dim
    half = hd // 2
    inv_freq = cfg.rope_theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    pos = jnp.arange(seq, dtype=jnp.float32)
    ang = pos[:, None] * inv_freq[None, :]  # [S, hd/2]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: [B, H, S, hd]; split-half rotation."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )


def attention(cfg: ModelConfig, params, prefix: str, x):
    """x: [B, S, D] -> [B, S, D], causal."""
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    q = (x @ params[prefix + "attn.wq"]).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k = (x @ params[prefix + "attn.wk"]).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    v = (x @ params[prefix + "attn.wv"]).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    cos, sin = rope_angles(cfg, s)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(hd)
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, d)
    return out @ params[prefix + "attn.wo"]


def ffn(cfg: ModelConfig, params, prefix: str, x):
    g = x @ params[prefix + "ffn.w_gate"]
    u = x @ params[prefix + "ffn.w_up"]
    return (jax.nn.silu(g) * u) @ params[prefix + "ffn.w_down"]


def forward_logits(cfg: ModelConfig, params, tokens):
    """tokens: i32[B, S] -> logits f32[B, S, V]."""
    x = params["embed"][tokens]
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        x = x + attention(cfg, params, p, rmsnorm(x, params[p + "ln_attn"], cfg.norm_eps))
        x = x + ffn(cfg, params, p, rmsnorm(x, params[p + "ln_ffn"], cfg.norm_eps))
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x @ params["head"]


def nll_per_token(cfg: ModelConfig, params, tokens):
    """Per-token next-token negative log likelihood.

    tokens: i32[B, S] -> nll f32[B, S-1]  (position t predicts token t+1)
    """
    logits = forward_logits(cfg, params, tokens)  # [B, S, V]
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:]
    return -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]


def loss_fn(cfg: ModelConfig, params, tokens):
    return jnp.mean(nll_per_token(cfg, params, tokens))


def forward_logits_vq_lastlayer(cfg: ModelConfig, params, tokens, idx_head, cb_head):
    """Forward pass with the unembedding matrix VQ-compressed and decoded
    through the L1 Pallas kernel — ties L1 into the L2 module so both lower
    into one HLO artifact (the `serve_vq` artifact used by rust).

    idx_head : i32[V, D//d] indices for head.T (row = output channel)
    cb_head  : f32[k, d]
    """
    from .kernels.vq_decode_matmul import vq_decode_matmul

    x = params["embed"][tokens]
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        x = x + attention(cfg, params, p, rmsnorm(x, params[p + "ln_attn"], cfg.norm_eps))
        x = x + ffn(cfg, params, p, rmsnorm(x, params[p + "ln_ffn"], cfg.norm_eps))
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    b, s, d = x.shape
    flat = x.reshape(b * s, d)
    # head is [D, V]; vq_decode_matmul wants row=output-channel, i.e. head.T
    logits = vq_decode_matmul(flat, idx_head, cb_head, tile_r=cfg.vocab)
    return logits.reshape(b, s, cfg.vocab)
