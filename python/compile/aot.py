"""AOT lowering: jax/pallas -> HLO *text* -> artifacts/ for the rust runtime.

Interchange format is HLO text, NOT `.serialize()`: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Artifacts produced (consumed by rust/src/runtime/):

  model_nll_<preset>.hlo.txt       nll_per_token(tokens,B=4,S=max_seq)
  model_logits_<preset>.hlo.txt    forward_logits(tokens,B=1,S=64)
  serve_vq_<preset>.hlo.txt        forward with VQ-decoded head via the
                                   L1 pallas vq_decode_matmul kernel
  vq_assign_d{d}_k{k}_n{n}.hlo.txt L1 pallas assignment kernel variants
  manifest.txt                     one line per artifact: name=file;meta

Argument order for model artifacts: tokens first, then parameters in
`model.param_names()` order — rust mirrors this schema.
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import PRESETS, forward_logits, forward_logits_vq_lastlayer, init_params, nll_per_token, param_names
from .kernels.vq_assign import vq_assign

NLL_BATCH = 4
LOGITS_BATCH = 1
LOGITS_SEQ = 64

# (d, k, n) variants for the EM/assignment hot loop. rust pads point count
# to n and centroid count to k (padding centroids at +1e30 so they are
# never selected).
ASSIGN_VARIANTS = [(1, 8, 4096), (2, 16, 4096), (2, 64, 4096), (4, 256, 4096)]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _param_specs(cfg, params):
    return [jax.ShapeDtypeStruct(params[n].shape, params[n].dtype) for n in param_names(cfg)]


def export_model(preset: str, out_dir: str, manifest: list[str]) -> None:
    cfg = PRESETS[preset]
    params = init_params(cfg, seed=0)
    names = param_names(cfg)
    specs = _param_specs(cfg, params)

    def nll_flat(tokens, *flat_params):
        p = dict(zip(names, flat_params))
        return (nll_per_token(cfg, p, tokens),)

    tok_spec = jax.ShapeDtypeStruct((NLL_BATCH, cfg.max_seq), jnp.int32)
    lowered = jax.jit(nll_flat).lower(tok_spec, *specs)
    path = f"model_nll_{preset}.hlo.txt"
    with open(os.path.join(out_dir, path), "w") as f:
        f.write(to_hlo_text(lowered))
    manifest.append(
        f"model_nll_{preset}={path};batch={NLL_BATCH};seq={cfg.max_seq};args=tokens+params"
    )

    def logits_flat(tokens, *flat_params):
        p = dict(zip(names, flat_params))
        return (forward_logits(cfg, p, tokens),)

    tok_spec = jax.ShapeDtypeStruct((LOGITS_BATCH, LOGITS_SEQ), jnp.int32)
    lowered = jax.jit(logits_flat).lower(tok_spec, *specs)
    path = f"model_logits_{preset}.hlo.txt"
    with open(os.path.join(out_dir, path), "w") as f:
        f.write(to_hlo_text(lowered))
    manifest.append(
        f"model_logits_{preset}={path};batch={LOGITS_BATCH};seq={LOGITS_SEQ};args=tokens+params"
    )


def export_serve_vq(preset: str, out_dir: str, manifest: list[str], d: int = 2, k: int = 16) -> None:
    """Model forward with VQ head decoded by the pallas kernel (L1 in L2)."""
    cfg = PRESETS[preset]
    params = init_params(cfg, seed=0)
    names = param_names(cfg)
    specs = _param_specs(cfg, params)
    idx_spec = jax.ShapeDtypeStruct((cfg.vocab, cfg.d_model // d), jnp.int32)
    cb_spec = jax.ShapeDtypeStruct((k, d), jnp.float32)

    def serve_flat(tokens, idx, cb, *flat_params):
        p = dict(zip(names, flat_params))
        return (forward_logits_vq_lastlayer(cfg, p, tokens, idx, cb),)

    tok_spec = jax.ShapeDtypeStruct((LOGITS_BATCH, LOGITS_SEQ), jnp.int32)
    lowered = jax.jit(serve_flat).lower(tok_spec, idx_spec, cb_spec, *specs)
    path = f"serve_vq_{preset}.hlo.txt"
    with open(os.path.join(out_dir, path), "w") as f:
        f.write(to_hlo_text(lowered))
    manifest.append(
        f"serve_vq_{preset}={path};batch={LOGITS_BATCH};seq={LOGITS_SEQ};d={d};k={k};"
        f"args=tokens+head_idx+head_cb+params"
    )


def export_assign(out_dir: str, manifest: list[str]) -> None:
    for d, k, n in ASSIGN_VARIANTS:
        pts = jax.ShapeDtypeStruct((n, d), jnp.float32)
        cbs = jax.ShapeDtypeStruct((k, d), jnp.float32)
        hds = jax.ShapeDtypeStruct((n, d), jnp.float32)
        lowered = jax.jit(lambda p, c, h: (vq_assign(p, c, h),)).lower(pts, cbs, hds)
        path = f"vq_assign_d{d}_k{k}_n{n}.hlo.txt"
        with open(os.path.join(out_dir, path), "w") as f:
            f.write(to_hlo_text(lowered))
        manifest.append(f"vq_assign_d{d}_k{k}_n{n}={path};d={d};k={k};n={n};args=points+centroids+hdiag")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--presets", default="tiny,small")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    manifest: list[str] = []
    for preset in args.presets.split(","):
        preset = preset.strip()
        if not preset:
            continue
        print(f"[aot] lowering model artifacts for preset={preset}", flush=True)
        export_model(preset, args.out, manifest)
        export_serve_vq(preset, args.out, manifest)
    print("[aot] lowering vq_assign kernel variants", flush=True)
    export_assign(args.out, manifest)
    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"[aot] wrote {len(manifest)} artifacts to {args.out}", flush=True)


if __name__ == "__main__":
    main()
