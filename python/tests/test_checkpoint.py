"""GVQCKPT1 container round-trip and format edge cases."""

import numpy as np
import pytest

from compile import checkpoint


def test_roundtrip_f32(tmp_path):
    tensors = {
        "a": np.random.default_rng(0).normal(size=(3, 4)).astype(np.float32),
        "b.nested.name": np.arange(10, dtype=np.float32),
    }
    p = str(tmp_path / "ck.bin")
    checkpoint.save(p, tensors)
    back = checkpoint.load(p)
    assert set(back) == set(tensors)
    for k in tensors:
        assert back[k].dtype == tensors[k].dtype
        assert np.array_equal(back[k], tensors[k])


def test_roundtrip_mixed_dtypes(tmp_path):
    tensors = {
        "f": np.ones((2, 2), np.float32),
        "i": np.array([[1, -2], [3, 4]], np.int32),
        "u8": np.arange(256, dtype=np.uint8),
        "u16": np.arange(1000, dtype=np.uint16),
    }
    p = str(tmp_path / "ck.bin")
    checkpoint.save(p, tensors)
    back = checkpoint.load(p)
    for k in tensors:
        assert back[k].dtype == tensors[k].dtype
        assert np.array_equal(back[k], tensors[k])


def test_scalar_and_empty(tmp_path):
    tensors = {
        "scalar": np.float32(3.5).reshape(()),
        "empty": np.zeros((0,), np.float32),
    }
    p = str(tmp_path / "ck.bin")
    checkpoint.save(p, {k: np.asarray(v) for k, v in tensors.items()})
    back = checkpoint.load(p)
    assert back["scalar"].shape == ()
    assert float(back["scalar"]) == 3.5
    assert back["empty"].shape == (0,)


def test_bad_magic_rejected(tmp_path):
    p = tmp_path / "bad.bin"
    p.write_bytes(b"WRONGMAG" + b"\x00" * 8)
    with pytest.raises(AssertionError):
        checkpoint.load(str(p))


def test_preserves_values_bitexact(tmp_path):
    # denormals, infinities, nan payloads must survive
    vals = np.array([0.0, -0.0, np.inf, -np.inf, np.nan, 1e-45, 3.14], np.float32)
    p = str(tmp_path / "ck.bin")
    checkpoint.save(p, {"v": vals})
    back = checkpoint.load(p)["v"]
    assert np.array_equal(back.view(np.uint32), vals.view(np.uint32))
