"""AOT lowering: HLO text is produced and structurally sound."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import to_hlo_text
from compile.kernels.vq_assign import vq_assign
from compile.model import ModelConfig, init_params, nll_per_token, param_names

CFG = ModelConfig(d_model=32, n_layers=1, n_heads=2, d_ffn=64, max_seq=16)


def test_assign_kernel_lowers_to_hlo_text():
    pts = jax.ShapeDtypeStruct((256, 2), jnp.float32)
    cbs = jax.ShapeDtypeStruct((16, 2), jnp.float32)
    hds = jax.ShapeDtypeStruct((256, 2), jnp.float32)
    lowered = jax.jit(lambda p, c, h: (vq_assign(p, c, h, tile_n=256),)).lower(pts, cbs, hds)
    text = to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "HloModule" in text


def test_model_nll_lowers_to_hlo_text():
    params = init_params(CFG, seed=0)
    names = param_names(CFG)
    specs = [jax.ShapeDtypeStruct(params[n].shape, params[n].dtype) for n in names]

    def nll_flat(tokens, *flat):
        p = dict(zip(names, flat))
        return (nll_per_token(CFG, p, tokens),)

    tok = jax.ShapeDtypeStruct((2, CFG.max_seq), jnp.int32)
    text = to_hlo_text(jax.jit(nll_flat).lower(tok, *specs))
    assert "ENTRY" in text
    # one parameter per weight tensor plus tokens
    assert text.count("parameter(") >= len(names) + 1


def test_hlo_text_has_31bit_ids():
    """The whole reason we ship text: ids must re-fit in 31 bits after the
    text round-trip (xla_extension 0.5.1 requirement)."""
    pts = jax.ShapeDtypeStruct((64, 1), jnp.float32)
    cbs = jax.ShapeDtypeStruct((8, 1), jnp.float32)
    hds = jax.ShapeDtypeStruct((64, 1), jnp.float32)
    lowered = jax.jit(lambda p, c, h: (vq_assign(p, c, h, tile_n=64),)).lower(pts, cbs, hds)
    text = to_hlo_text(lowered)
    # text form should never carry gigantic id literals
    import re

    for tok in re.findall(r"%[A-Za-z_.\-]*([0-9]{10,})", text):
        assert int(tok) < 2**31


def test_lowered_nll_executes_and_matches_eager():
    params = init_params(CFG, seed=1)
    names = param_names(CFG)

    def nll_flat(tokens, *flat):
        p = dict(zip(names, flat))
        return (nll_per_token(CFG, p, tokens),)

    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, 256, size=(2, CFG.max_seq)).astype(np.int32))
    flat = [params[n] for n in names]
    compiled = jax.jit(nll_flat).lower(toks, *flat).compile()
    got = np.asarray(compiled(toks, *flat)[0])
    want = np.asarray(nll_per_token(CFG, params, toks))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
