"""Step-time simulation mirror of the Rust engine's overload contract.

The serving engine (rust/src/serve) makes every overload decision in
deterministic step-time: a bounded admission queue sheds at submit, a
per-request deadline expires a request a fixed number of engine steps
after submission, and a FIFO scheduler admits into `max_batch` decode
slots that each emit one token per step. This module re-implements that
arithmetic as a tiny discrete-event model and asserts the same
invariants the Rust property harness (rust/tests/engine_overload.rs)
and the overload-ladder bench pin:

* every offered request resolves exactly once (completed | shed |
  expired),
* the bounded queue never exceeds its cap,
* shed count is monotone in offered load,
* goodput saturates instead of collapsing at 4x overload,
* identically-seeded runs are identical.

No JAX, no hypothesis — the point is that the *contract* is simple
enough to state in 100 lines of stdlib Python, so a divergence in the
Rust implementation is a bug there, not ambiguity here.
"""

import random
from dataclasses import dataclass


@dataclass
class Request:
    rid: int
    tokens: int          # output budget (steps to complete, 1 tok/step)
    deadline_steps: int  # 0 = none; expires when waited >= deadline


def bounded_pareto(rng, alpha, lo, hi):
    """Inverse-CDF draw from a bounded Pareto, clamped to [lo, hi]."""
    u = rng.random()
    a = 1.0 - (lo / hi) ** alpha
    x = lo * (1.0 - u * a) ** (-1.0 / alpha)
    return max(lo, min(hi, int(x)))


def poisson(rng, lam):
    """Knuth's product-of-uniforms Poisson draw (exact, small lambda)."""
    import math

    limit = math.exp(-lam)
    k, p = 0, 1.0
    while True:
        p *= rng.random()
        if p <= limit:
            return k
        k += 1


def generate(seed, rate, requests, deadline_steps=0, out_lo=2, out_hi=24):
    """Seeded open-loop schedule: [(arrival_step, Request)] with ids in
    arrival order — the Python twin of serve::loadgen::generate."""
    rng = random.Random(seed)
    arrivals, step = [], 0
    while len(arrivals) < requests:
        burst = step % 64 < 16  # burst phases, as in the Rust default
        for _ in range(poisson(rng, rate * (4.0 if burst else 1.0))):
            if len(arrivals) >= requests:
                break
            tokens = bounded_pareto(rng, 1.5, out_lo, out_hi)
            arrivals.append((step, Request(len(arrivals), tokens, deadline_steps)))
        step += 1
    return arrivals


def simulate(arrivals, max_batch=4, queue_cap=8):
    """Run the schedule through the step-time overload model. Returns
    (resolutions: {rid: outcome}, goodput_tokens, clock_steps)."""
    queue = []   # (rid, tokens, deadline, submit_step)
    slots = []   # [rid, remaining, deadline, submit_step]
    resolved = {}

    def resolve(rid, outcome):
        assert rid not in resolved, f"request {rid} resolved twice"
        resolved[rid] = outcome

    goodput = 0
    step = 0
    nxt = 0
    while nxt < len(arrivals) or queue or slots:
        # arrivals whose step has come: shed on a full bounded queue
        while nxt < len(arrivals) and arrivals[nxt][0] <= step:
            _, req = arrivals[nxt]
            nxt += 1
            if queue_cap and len(queue) >= queue_cap:
                resolve(req.rid, "shed")
            else:
                queue.append((req.rid, req.tokens, req.deadline_steps, step))
        assert not queue_cap or len(queue) <= queue_cap
        # deadline sweep (start of step, before admission — freed slots
        # readmit the same step, exactly like Core::step)
        for s in [s for s in slots if s[2] and step - s[3] >= s[2]]:
            slots.remove(s)
            resolve(s[0], "expired")
        for q in [q for q in queue if q[2] and step - q[3] >= q[2]]:
            queue.remove(q)
            resolve(q[0], "expired")
        # FIFO admission into free slots
        while queue and len(slots) < max_batch:
            rid, tokens, dl, sub = queue.pop(0)
            slots.append([rid, tokens, dl, sub])
        # decode: one token per active slot per step
        for s in slots:
            s[1] -= 1
        for s in [s for s in slots if s[1] <= 0]:
            slots.remove(s)
            goodput += dict((a[1].rid, a[1].tokens) for a in arrivals)[s[0]]
            resolve(s[0], "completed")
        step += 1
    return resolved, goodput, step


def ladder(seed=11, base_requests=96):
    """Offered-load ladder at ~0.5x/1x/2x/4x of the 4-token/step
    capacity (mean output ~4.4 tokens at the Pareto defaults). Request
    count scales with the rate so every rung spans a comparable number
    of arrival steps — otherwise the high rungs are all ragged
    drain-tail and goodput undercounts saturation."""
    out = []
    for mult, rate in ((0.5, 0.45), (1.0, 0.9), (2.0, 1.8), (4.0, 3.6)):
        n = int(base_requests * mult)
        arrivals = generate(seed, rate, n, deadline_steps=64)
        resolved, goodput, steps = simulate(arrivals)
        out.append((rate, resolved, goodput, steps))
    return out


def test_every_request_resolves_exactly_once():
    for n, (rate, resolved, _, _) in zip((48, 96, 192, 384), ladder()):
        assert len(resolved) == n, f"rate {rate}: {len(resolved)} resolutions"
        assert set(resolved) == set(range(n))
        assert set(resolved.values()) <= {"completed", "shed", "expired"}


def test_shed_rate_is_monotone_in_offered_load():
    fracs = [
        sum(1 for o in r.values() if o == "shed") / len(r) for _, r, _, _ in ladder()
    ]
    assert fracs == sorted(fracs), f"shed fraction not monotone: {fracs}"


def test_goodput_saturates_instead_of_collapsing():
    rungs = ladder()
    per_step = [g / s for _, _, g, s in rungs]
    plateau, at_4x = per_step[1], per_step[3]
    assert at_4x >= 0.8 * plateau, f"goodput collapsed: {at_4x:.2f} vs {plateau:.2f}"


def test_identical_seeds_are_identical_runs():
    a = ladder(seed=23)
    b = ladder(seed=23)
    for (_, ra, ga, sa), (_, rb, gb, sb) in zip(a, b):
        assert ra == rb and ga == gb and sa == sb


def test_deadline_zero_means_no_expiry_and_unbounded_queue_never_sheds():
    arrivals = generate(3, 3.6, 48, deadline_steps=0)
    resolved, _, _ = simulate(arrivals, max_batch=2, queue_cap=0)
    assert set(resolved.values()) == {"completed"}


def test_infeasible_load_with_tight_deadlines_still_resolves_all():
    arrivals = generate(5, 3.6, 64, deadline_steps=6)
    resolved, goodput, _ = simulate(arrivals, max_batch=2, queue_cap=3)
    assert len(resolved) == 64
    assert sum(1 for o in resolved.values() if o == "expired") > 0
    assert goodput >= 0
