"""L1 Pallas kernels vs pure-jnp oracles (the core correctness signal).

hypothesis sweeps shapes; every kernel must match ref.py bit-for-bit on
assignment indices and to float tolerance on matmuls.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    ref_vq_assign,
    ref_vq_assign_dist,
    ref_vq_decode,
    ref_vq_decode_matmul,
    vq_assign,
    vq_decode_matmul,
)

RNG = np.random.default_rng(0)


def _mk(n, d, k, seed=0):
    rng = np.random.default_rng(seed)
    pts = rng.normal(size=(n, d)).astype(np.float32)
    cbs = rng.normal(size=(k, d)).astype(np.float32)
    hdg = rng.uniform(0.1, 2.0, size=(n, d)).astype(np.float32)
    return jnp.asarray(pts), jnp.asarray(cbs), jnp.asarray(hdg)


class TestVqAssign:
    @pytest.mark.parametrize("d,k", [(1, 8), (2, 16), (2, 64), (4, 256)])
    def test_matches_ref_paper_settings(self, d, k):
        pts, cbs, hdg = _mk(1024, d, k, seed=d * 100 + k)
        got = np.asarray(vq_assign(pts, cbs, hdg, tile_n=256))
        want = np.asarray(ref_vq_assign(pts, cbs, hdg))
        assert np.array_equal(got, want)

    @settings(max_examples=25, deadline=None)
    @given(
        logn=st.integers(5, 10),
        d=st.sampled_from([1, 2, 4]),
        k=st.sampled_from([2, 4, 16, 32]),
        seed=st.integers(0, 10_000),
    )
    def test_matches_ref_hypothesis(self, logn, d, k, seed):
        n = 2**logn
        pts, cbs, hdg = _mk(n, d, k, seed=seed)
        tile = min(256, n)
        got = np.asarray(vq_assign(pts, cbs, hdg, tile_n=tile))
        want = np.asarray(ref_vq_assign(pts, cbs, hdg))
        assert np.array_equal(got, want)

    def test_identity_hessian_is_plain_kmeans_assign(self):
        pts, cbs, _ = _mk(512, 2, 16, seed=3)
        ones = jnp.ones_like(pts)
        got = np.asarray(vq_assign(pts, cbs, ones, tile_n=512))
        # plain euclidean nearest
        d2 = np.sum(
            (np.asarray(pts)[:, None] - np.asarray(cbs)[None]) ** 2, axis=-1
        )
        want = np.argmin(d2, axis=-1)
        assert np.array_equal(got, want)

    def test_hessian_weighting_changes_assignment(self):
        # two centroids along x and y; the Hessian weight decides proximity
        pts = jnp.asarray([[1.0, 1.0]], dtype=jnp.float32)
        cbs = jnp.asarray([[1.5, 0.0], [0.0, 1.2]], dtype=jnp.float32)
        hx = jnp.asarray([[10.0, 0.1]], dtype=jnp.float32)  # x errors costly
        hy = jnp.asarray([[0.1, 10.0]], dtype=jnp.float32)  # y errors costly
        ax = int(vq_assign(pts, cbs, hx, tile_n=1)[0])
        ay = int(vq_assign(pts, cbs, hy, tile_n=1)[0])
        assert ax == 0 and ay == 1

    def test_exact_centroid_hit(self):
        _, cbs, hdg = _mk(16, 2, 16, seed=5)
        pts = cbs[:16]
        got = np.asarray(vq_assign(pts, cbs, hdg[:16], tile_n=16))
        assert np.array_equal(got, np.arange(16))

    def test_zero_hdiag_gives_index_zero_everywhere(self):
        pts, cbs, _ = _mk(64, 2, 8, seed=9)
        zero = jnp.zeros_like(pts)
        got = np.asarray(vq_assign(pts, cbs, zero, tile_n=64))
        assert np.array_equal(got, np.zeros(64, dtype=np.int32))

    def test_padding_centroids_never_selected(self):
        # rust pads codebooks to the AOT k with +1e30 sentinels
        pts, cbs, hdg = _mk(256, 2, 8, seed=13)
        pad = jnp.full((8, 2), 1e30, dtype=jnp.float32)
        padded = jnp.concatenate([cbs, pad], axis=0)
        got = np.asarray(vq_assign(pts, padded, hdg, tile_n=256))
        assert got.max() < 8
        want = np.asarray(ref_vq_assign(pts, cbs, hdg))
        assert np.array_equal(got, want)


class TestVqDecodeMatmul:
    @pytest.mark.parametrize("d,k", [(1, 8), (2, 16), (4, 64)])
    def test_matches_ref(self, d, k):
        rng = np.random.default_rng(d + k)
        b, c, r = 4, 32, 64
        x = jnp.asarray(rng.normal(size=(b, c)).astype(np.float32))
        idx = jnp.asarray(rng.integers(0, k, size=(r, c // d)).astype(np.int32))
        cb = jnp.asarray(rng.normal(size=(k, d)).astype(np.float32))
        got = np.asarray(vq_decode_matmul(x, idx, cb, tile_r=32))
        want = np.asarray(ref_vq_decode_matmul(x, idx, cb))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    @settings(max_examples=15, deadline=None)
    @given(
        b=st.sampled_from([1, 2, 8]),
        d=st.sampled_from([1, 2, 4]),
        logk=st.integers(1, 6),
        seed=st.integers(0, 10_000),
    )
    def test_matches_ref_hypothesis(self, b, d, logk, seed):
        k = 2**logk
        rng = np.random.default_rng(seed)
        c, r = 16 * d, 32
        x = jnp.asarray(rng.normal(size=(b, c)).astype(np.float32))
        idx = jnp.asarray(rng.integers(0, k, size=(r, c // d)).astype(np.int32))
        cb = jnp.asarray(rng.normal(size=(k, d)).astype(np.float32))
        got = np.asarray(vq_decode_matmul(x, idx, cb, tile_r=r))
        want = np.asarray(ref_vq_decode_matmul(x, idx, cb))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_decode_layout(self):
        # W[i, j*d+t] = cb[idx[i,j], t]
        cb = jnp.asarray([[0.0, 1.0], [10.0, 11.0]], dtype=jnp.float32)
        idx = jnp.asarray([[0, 1], [1, 0]], dtype=jnp.int32)
        w = np.asarray(ref_vq_decode(idx, cb))
        assert w.tolist() == [[0.0, 1.0, 10.0, 11.0], [10.0, 11.0, 0.0, 1.0]]

    def test_tiled_equals_untiled(self):
        rng = np.random.default_rng(77)
        x = jnp.asarray(rng.normal(size=(2, 8)).astype(np.float32))
        idx = jnp.asarray(rng.integers(0, 4, size=(64, 4)).astype(np.int32))
        cb = jnp.asarray(rng.normal(size=(4, 2)).astype(np.float32))
        a = np.asarray(vq_decode_matmul(x, idx, cb, tile_r=16))
        b = np.asarray(vq_decode_matmul(x, idx, cb, tile_r=64))
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


class TestVmemModel:
    def test_assign_vmem_under_budget(self):
        from compile.kernels.vq_assign import vmem_bytes

        # every paper setting with the default tile must fit 16MB VMEM
        for d, k in [(1, 8), (2, 16), (2, 64), (4, 256), (4, 4096)]:
            assert vmem_bytes(512, d, k) < 16 * 2**20

    def test_decode_matmul_vmem_under_budget(self):
        from compile.kernels.vq_decode_matmul import vmem_bytes

        assert vmem_bytes(8, 1024, 256, 256, 4) < 16 * 2**20
