"""Corpus generator: determinism, token range, split disjointness."""

import numpy as np
import pytest

from compile import corpus


def test_deterministic():
    a = corpus.generate_text(42, 10_000)
    b = corpus.generate_text(42, 10_000)
    assert a == b


def test_seed_changes_text():
    a = corpus.generate_text(1, 5_000)
    b = corpus.generate_text(2, 5_000)
    assert a != b


def test_length():
    text = corpus.generate_text(7, 12_345)
    assert len(text) == 12_345


def test_tokens_are_bytes():
    toks = corpus.tokenize(corpus.generate_text(3, 5_000))
    assert toks.dtype == np.uint8
    assert toks.min() >= 0 and toks.max() < 256


def test_text_looks_like_english():
    text = corpus.generate_text(11, 20_000)
    # sentences end with periods, words are space separated
    assert text.count(".") > 100
    assert text.count(" ") > 1000
    words = text.replace(".", " ").split()
    # high-frequency function words should appear
    assert "the" in words


def test_splits_disjoint_streams():
    train, valid = corpus.build_splits(123, 50_000, 10_000)
    assert len(train) == 50_000 and len(valid) == 10_000
    # different generator streams -> different content
    assert not np.array_equal(train[:10_000], valid)


def test_token_roundtrip(tmp_path):
    toks = corpus.tokenize(corpus.generate_text(9, 4_096))
    p = str(tmp_path / "toks.bin")
    corpus.write_tokens(p, toks)
    back = corpus.read_tokens(p)
    assert np.array_equal(toks, back)


def test_token_read_rejects_bad_magic(tmp_path):
    p = tmp_path / "bad.bin"
    p.write_bytes(b"NOTMAGIC" + b"\x00" * 16)
    with pytest.raises(AssertionError):
        corpus.read_tokens(str(p))


def test_zipf_distribution_is_skewed():
    text = corpus.generate_text(5, 200_000)
    words = text.replace(".", "").replace(",", "").lower().split()
    from collections import Counter

    counts = Counter(words)
    freqs = sorted(counts.values(), reverse=True)
    # top word should be much more frequent than the median word
    assert freqs[0] > 10 * freqs[len(freqs) // 2]
