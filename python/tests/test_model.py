"""L2 model: shapes, causality, RoPE properties, trainability."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    PRESETS,
    ModelConfig,
    apply_rope,
    forward_logits,
    forward_logits_vq_lastlayer,
    init_params,
    loss_fn,
    nll_per_token,
    param_names,
    rope_angles,
)

CFG = ModelConfig(d_model=32, n_layers=2, n_heads=2, d_ffn=64, max_seq=32)


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, seed=0)


def _tokens(b, s, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, CFG.vocab, size=(b, s)).astype(np.int32))


def test_param_schema_complete(params):
    assert set(params) == set(param_names(CFG))


def test_param_count_formula(params):
    total = sum(int(np.prod(v.shape)) for v in params.values())
    assert total == CFG.param_count()


def test_logits_shape(params):
    toks = _tokens(2, 16)
    logits = forward_logits(CFG, params, toks)
    assert logits.shape == (2, 16, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_causality(params):
    """Changing token t must not change logits at positions < t."""
    toks = _tokens(1, 16, seed=1)
    base = forward_logits(CFG, params, toks)
    toks2 = toks.at[0, 10].set((toks[0, 10] + 1) % CFG.vocab)
    pert = forward_logits(CFG, params, toks2)
    np.testing.assert_allclose(
        np.asarray(base[0, :10]), np.asarray(pert[0, :10]), atol=1e-5
    )
    assert not np.allclose(np.asarray(base[0, 10:]), np.asarray(pert[0, 10:]))


def test_nll_consistent_with_logits(params):
    toks = _tokens(2, 12, seed=3)
    logits = forward_logits(CFG, params, toks)
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    want = -np.take_along_axis(
        np.asarray(logp), np.asarray(toks[:, 1:])[..., None], axis=-1
    )[..., 0]
    got = np.asarray(nll_per_token(CFG, params, toks))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_rope_preserves_norm():
    cos, sin = rope_angles(CFG, 8)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1, 2, 8, CFG.head_dim)).astype(np.float32))
    y = apply_rope(x, cos, sin)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-5,
    )


def test_rope_position_zero_is_identity():
    cos, sin = rope_angles(CFG, 4)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(1, 1, 4, CFG.head_dim)).astype(np.float32))
    y = apply_rope(x, cos, sin)
    np.testing.assert_allclose(np.asarray(x[0, 0, 0]), np.asarray(y[0, 0, 0]), rtol=1e-6)


def test_rope_relative_property():
    """q.k after rope depends only on relative position (same head vec)."""
    cos, sin = rope_angles(CFG, 16)
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(CFG.head_dim,)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(CFG.head_dim,)).astype(np.float32))

    def dot_at(i, j):
        qe = apply_rope(q[None, None, None, :], cos[i : i + 1], sin[i : i + 1])
        ke = apply_rope(k[None, None, None, :], cos[j : j + 1], sin[j : j + 1])
        return float(jnp.sum(qe * ke))

    assert abs(dot_at(3, 1) - dot_at(10, 8)) < 1e-4
    assert abs(dot_at(5, 5) - dot_at(12, 12)) < 1e-4


def test_loss_decreases_with_sgd(params):
    toks = _tokens(4, 32, seed=5)
    loss0 = float(loss_fn(CFG, params, toks))
    grads = jax.grad(lambda p: loss_fn(CFG, p, toks))(params)
    p2 = {k: params[k] - 0.5 * grads[k] for k in params}
    loss1 = float(loss_fn(CFG, p2, toks))
    assert loss1 < loss0


def test_initial_loss_near_uniform(params):
    toks = _tokens(4, 32, seed=6)
    loss = float(loss_fn(CFG, params, toks))
    assert abs(loss - np.log(CFG.vocab)) < 0.5


def test_vq_lastlayer_matches_dense_when_codebook_exact(params):
    """If the codebook perfectly encodes head.T, the VQ forward must equal
    the dense forward (ties L1 kernel semantics to L2)."""
    d = 2
    head_t = np.asarray(params["head"]).T  # [V, D]
    v, dm = head_t.shape
    vecs = head_t.reshape(v * dm // d, d)
    # build an exact codebook: use all unique strips (small model -> take
    # the first 2^14 strips is overkill; instead quantize to itself by
    # using every strip as its own centroid is too big — so instead test
    # with a *random* small codebook and compare against ref decode.)
    rng = np.random.default_rng(0)
    k = 16
    cb = rng.normal(size=(k, d)).astype(np.float32)
    idx = rng.integers(0, k, size=(v, dm // d)).astype(np.int32)
    toks = _tokens(1, 16, seed=7)
    got = forward_logits_vq_lastlayer(CFG, params, toks, jnp.asarray(idx), jnp.asarray(cb))
    # reference: decode and run dense with replaced head
    w = cb[idx].reshape(v, dm)  # [V, D]
    p2 = dict(params)
    p2["head"] = jnp.asarray(w.T)
    want = forward_logits(CFG, p2, toks)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_presets_are_consistent():
    for name, cfg in PRESETS.items():
        assert cfg.d_model % cfg.n_heads == 0, name
        assert cfg.head_dim % 2 == 0, name
        assert cfg.vocab == 256, name
