"""Zero-shot probe task generation and GVQTASK1 format."""

import numpy as np
import pytest

from compile import tasks


@pytest.mark.parametrize("name", sorted(tasks.TASKS))
def test_generation_and_roundtrip(name, tmp_path):
    items = tasks.TASKS[name](seed=7, n_items=24)
    assert len(items) == 24
    for prompt, choices, correct in items:
        assert len(choices) == tasks.N_CHOICES
        assert 0 <= correct < tasks.N_CHOICES
        assert len(prompt) > 0
        assert all(len(c) > 0 for c in choices)
    p = str(tmp_path / f"{name}.bin")
    tasks.write_task(p, items)
    back = tasks.read_task(p)
    assert back == items


def test_correct_answer_distribution():
    items = tasks.make_cloze(seed=3, n_items=100)
    counts = np.bincount([c for _, _, c in items], minlength=tasks.N_CHOICES)
    # answers are randomly placed: no slot should dominate
    assert counts.max() < 60


def test_cloze_correct_choice_is_genuine_suffix():
    items = tasks.make_cloze(seed=11, n_items=10)
    for prompt, choices, correct in items:
        assert choices[correct].endswith(".")


def test_induction_pattern_structure():
    items = tasks.make_induction(seed=5, n_items=10)
    for prompt, choices, correct in items:
        words = prompt.split()
        assert "." in words
        dot = words.index(".")
        # prefix before '.' is 4 words, repeated prefix after is 3
        assert dot == 4 and len(words) == 8
        assert words[:3] == words[5:8]
        assert choices[correct] == words[3]


def test_write_all(tmp_path):
    tasks.write_all(str(tmp_path), n_items=8, seed=1)
    import os

    for name in tasks.TASKS:
        assert os.path.exists(tmp_path / f"task_{name}.bin")


def test_determinism():
    a = tasks.make_pair(seed=9, n_items=12)
    b = tasks.make_pair(seed=9, n_items=12)
    assert a == b
